"""Small residual CNN / MLP image classifiers — the paper's client models.

The VAFL paper trains a small ResNet on MNIST on Raspberry-Pi clients; we
reproduce that scale with a compact residual CNN (conv stem + residual
blocks + pooled linear head) plus an even cheaper MLP used by fast unit
tests.  Both are pure-JAX with params-dict structure matching the rest of
the zoo, so the FL runtime treats them like any other architecture.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.factory import ParamFactory


@dataclass(frozen=True)
class CNNConfig:
    name: str = "vafl_cnn"
    image_size: int = 28
    channels: Tuple[int, ...] = (16, 32)
    num_blocks: int = 2
    num_classes: int = 10
    param_dtype: str = "float32"
    arch_type: str = "cnn"
    source: str = "VAFL paper Fig.2 (ResNet on MNIST, reproduced at matching scale)"


@dataclass(frozen=True)
class MLPConfig:
    name: str = "vafl_mlp"
    image_size: int = 28
    hidden: Tuple[int, ...] = (128, 64)
    num_classes: int = 10
    param_dtype: str = "float32"
    arch_type: str = "mlp"
    source: str = "fast-test stand-in for the paper's client model"


# ------------------------------------------------------------------ CNN ---

def _conv_init(fac, cin, cout, k=3):
    return {"w": fac.param((k, k, cin, cout), (None, None, None, None), init="normal",
                           scale=(2.0 / (k * k * cin)) ** 0.5),
            "b": fac.param((cout,), (None,), init="zeros")}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def cnn_init(cfg: CNNConfig, key):
    fac = ParamFactory(key=key, dtype=jnp.dtype(cfg.param_dtype))
    c0 = cfg.channels[0]
    params = {"stem": _conv_init(fac, 1, c0), "blocks": [], "proj": []}
    cin = c0
    for ci in cfg.channels:
        for _ in range(cfg.num_blocks):
            params["blocks"].append({
                "c1": _conv_init(fac, cin, ci), "c2": _conv_init(fac, ci, ci),
                "proj": _conv_init(fac, cin, ci, k=1) if cin != ci else None,
            })
            cin = ci
    params["head"] = {"w": fac.param((cin, cfg.num_classes), (None, None)),
                      "b": fac.param((cfg.num_classes,), (None,), init="zeros")}
    return params


def cnn_forward(cfg: CNNConfig, params, images):
    """images (B, H, W) or (B, H, W, 1) -> logits (B, classes)."""
    x = images if images.ndim == 4 else images[..., None]
    x = jax.nn.relu(_conv(params["stem"], x))
    for bp in params["blocks"]:
        stride = 2 if bp["proj"] is not None else 1  # downsample on stage change
        h = jax.nn.relu(_conv(bp["c1"], x, stride))
        h = _conv(bp["c2"], h)
        sc = x if bp["proj"] is None else _conv(bp["proj"], x, stride)
        x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ------------------------------------------------------------------ MLP ---

def mlp_init(cfg: MLPConfig, key):
    fac = ParamFactory(key=key, dtype=jnp.dtype(cfg.param_dtype))
    dims = (cfg.image_size * cfg.image_size,) + cfg.hidden + (cfg.num_classes,)
    return {"layers": [{"w": fac.param((a, b), (None, None)),
                        "b": fac.param((b,), (None,), init="zeros")}
                       for a, b in zip(dims[:-1], dims[1:])]}


def mlp_forward(cfg: MLPConfig, params, images):
    x = images.reshape(images.shape[0], -1)
    for i, lp in enumerate(params["layers"]):
        x = x @ lp["w"] + lp["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------- shared loss ---

def classifier_loss(forward_fn, cfg, params, batch):
    """batch {"images": (B,H,W), "labels": (B,)} -> (loss, metrics)."""
    logits = forward_fn(cfg, params, batch["images"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(nll), {"acc": acc}
