"""Linear-recurrence blocks: Mamba2 (SSD) and RWKV6 (Finch).

Both are instances of the gated linear recurrence

    S_t = diag(exp(log_a_t)) @ S_{t-1} + k_t v_t^T          S: (K, V)
    y_t = q_t^T S_t                      (include_current=True, Mamba2)
    y_t = q_t^T (S_{t-1} + diag(u) k_t v_t^T)               (RWKV6 bonus)

computed with a two-level chunked algorithm: an exact intra-chunk pass and
a short cross-chunk scan of states — the TPU-friendly SSD decomposition
(sequential depth = chunk + n_chunks instead of S).

Numerics: per-HEAD scalar decay (Mamba2) uses the exact exponent-difference
score matrix.  Per-DIM decay (RWKV6) uses the factorised q*exp(c) / k*exp(-c)
form, which is exact while |cumulative chunk decay| stays inside fp32
exponent range; we clamp per-step log-decay at LOG_A_MIN and use chunk<=64
so the factorisation cannot overflow (see DESIGN.md hardware notes).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.factory import ParamFactory
from repro.models.layers import apply_group_norm, init_group_norm

LOG_A_MIN = -8.0  # per-step clamp for per-dim decay (chunk<=64 -> exp<=512 safe in fp32)


# ============================================ chunked linear recurrence ===

def linear_recurrence_scan(q, k, v, log_a, u=None, include_current=True,
                           initial_state=None):
    """Exact sequential reference. q,k,log_a (B,S,H,K); v (B,S,H,V).
    Returns y (B,S,H,V), final state (B,H,K,V)."""
    B, S, H, K = q.shape
    V = v.shape[-1]
    S0 = initial_state if initial_state is not None else jnp.zeros((B, H, K, V), jnp.float32)

    def step(state, inp):
        qt, kt, vt, lat = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]
        if include_current:
            new = jnp.exp(lat)[..., None] * state + kv
            y = jnp.einsum("bhk,bhkv->bhv", qt, new)
        else:
            att = state + (u[None, :, :, None] * kv if u is not None else kv)
            y = jnp.einsum("bhk,bhkv->bhv", qt, att)
            new = jnp.exp(lat)[..., None] * state + kv
        return new, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (q, k, v, log_a))
    final, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), final


def _intra_chunk_per_head(qc, kc, vc, la, u, include_current):
    """Exact intra-chunk for per-head *scalar* decay.
    qc,kc (B,N,L,H,K) with la (B,N,L,H) scalar decays; vc (B,N,L,H,V)."""
    cum = jnp.cumsum(la, axis=2)                                # (B,N,L,H)
    # score[t,s] = (q_t . k_s) * exp(cum_t - cum_s)   for s<=t (or s<t)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,N,L,L,H)
    L = qc.shape[2]
    tri = jnp.tril(jnp.ones((L, L), bool), 0 if include_current else -1)
    dec = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    dots = jnp.einsum("bnthk,bnshk->bntsh", qc, kc)
    scores = dots * dec
    if not include_current and u is not None:
        cur = jnp.einsum("bnthk,hk,bnthk->bnth", qc, u, kc)
        scores = scores + cur[:, :, :, None, :] * jnp.eye(L)[None, None, :, :, None]
    y = jnp.einsum("bntsh,bnshv->bnthv", scores, vc)
    return y, cum


def _intra_chunk_per_dim(qc, kc, vc, la, u, include_current):
    """Factorised intra-chunk for per-dim decay. la (B,N,L,H,K)."""
    cum = jnp.cumsum(la, axis=2)                                # (B,N,L,H,K)
    qf = qc * jnp.exp(cum if include_current else cum - la)
    kf = kc * jnp.exp(-cum)
    L = qc.shape[2]
    tri = jnp.tril(jnp.ones((L, L), bool), 0 if include_current else -1)
    scores = jnp.einsum("bnthk,bnshk->bntsh", qf, kf)
    scores = jnp.where(tri[None, None, :, :, None], scores, 0.0)
    if not include_current and u is not None:
        cur = jnp.einsum("bnthk,hk,bnthk->bnth", qc, u, kc)
        scores = scores + cur[:, :, :, None, :] * jnp.eye(L)[None, None, :, :, None]
    y = jnp.einsum("bntsh,bnshv->bnthv", scores, vc)
    return y, cum


def linear_recurrence(q, k, v, log_a, u=None, include_current=True,
                      initial_state=None, chunk: int = 64,
                      decay_per: str = "dim") -> Tuple[jax.Array, jax.Array]:
    """Two-level chunked linear recurrence.  Shapes as in the scan reference.
    log_a for decay_per=="head" may be (B,S,H) (scalar per head)."""
    B, S, H, K = q.shape
    V = v.shape[-1]
    orig_S = S
    if S % chunk:
        pad = chunk - S % chunk
        zq = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        q, k, v = zq(q), zq(k), zq(v)
        log_a = zq(log_a)
        S = q.shape[1]
    N, L = S // chunk, chunk

    f32 = jnp.float32
    qc = q.reshape(B, N, L, H, K).astype(f32)
    kc = k.reshape(B, N, L, H, K).astype(f32)
    vc = v.reshape(B, N, L, H, V).astype(f32)

    if decay_per == "head":
        la = (log_a if log_a.ndim == 3 else log_a[..., 0]).reshape(B, N, L, H).astype(f32)
        y_intra, cum = _intra_chunk_per_head(qc, kc, vc, la, u, include_current)
        cum_k = cum[..., None]                                  # (B,N,L,H,1)
    else:
        la = jnp.clip(log_a.reshape(B, N, L, H, K).astype(f32), LOG_A_MIN, 0.0)
        y_intra, cum = _intra_chunk_per_dim(qc, kc, vc, la, u, include_current)
        cum_k = cum                                             # (B,N,L,H,K)

    # chunk-local end states: S_loc = sum_s exp(cum_L - cum_s) k_s v_s^T
    tot = cum_k[:, :, -1:, :, :]                                # (B,N,1,H,K)
    kdec = kc * jnp.exp(tot - cum_k)
    s_loc = jnp.einsum("bnlhk,bnlhv->bnhkv", kdec, vc)          # (B,N,H,K,V)
    tot = tot[:, :, 0]                                          # (B,N,H,K)

    # cross-chunk scan: S_in[n] = state before chunk n
    S0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((B, H, K, V), f32))

    def xstep(state, inp):
        t, sl = inp  # (B,H,K), (B,H,K,V)
        new = jnp.exp(t)[..., None] * state + sl
        return new, state

    final, s_in = jax.lax.scan(xstep, S0,
                               (jnp.moveaxis(tot, 1, 0), jnp.moveaxis(s_loc, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                             # (B,N,H,K,V)

    # inter-chunk contribution: y_t += (q_t * exp(cum_{t(-1)})) . S_in
    shift = cum_k if include_current else cum_k - (la[..., None] if decay_per == "head" else la)
    qdec = qc * jnp.exp(shift)
    y_inter = jnp.einsum("bnlhk,bnhkv->bnlhv", qdec, s_in)
    y = (y_intra + y_inter).reshape(B, S, H, V)[:, :orig_S].astype(v.dtype)
    return y, final


def recurrence_decode_step(state, qt, kt, vt, la_t, u=None, include_current=True):
    """One-token state update. state (B,H,K,V); qt/kt/la_t (B,H,K); vt (B,H,V)."""
    f32 = jnp.float32
    out_dtype = vt.dtype
    qt, kt, vt, la_t = (t.astype(f32) for t in (qt, kt, vt, la_t))
    kv = kt[..., :, None] * vt[..., None, :]
    if include_current:
        new = jnp.exp(la_t)[..., None] * state + kv
        y = jnp.einsum("bhk,bhkv->bhv", qt, new)
    else:
        att = state + (u[None, :, :, None] * kv if u is not None else kv)
        y = jnp.einsum("bhk,bhkv->bhv", qt, att)
        new = jnp.exp(la_t)[..., None] * state + kv
    return y.astype(out_dtype), new


# ================================================================ Mamba2 ===

def init_mamba2(fac: ParamFactory, cfg):
    d, s = cfg.d_model, cfg.ssm
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.state_dim
    return {
        "in_proj": fac.param((d, 2 * d_in + 2 * s.state_dim + nheads), ("embed", "mlp")),
        "conv_w": fac.param((s.conv_width, conv_dim), (None, "mlp")),
        "conv_b": fac.param((conv_dim,), ("mlp",), init="zeros"),
        "dt_bias": fac.param((nheads,), (None,), init="zeros"),
        "A_log": fac.param((nheads,), (None,), init="constant", scale=0.0),
        "D": fac.param((nheads,), (None,), init="ones"),
        "norm_scale": fac.param((d_in,), ("mlp",), init="ones"),
        "out_proj": fac.param((d_in, d), ("mlp", "embed")),
    }


def _mamba_split(p, cfg, x):
    d, s = cfg.d_model, cfg.ssm
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + s.state_dim, 2 * d_in + 2 * s.state_dim], axis=-1)
    return z, xin, Bc, Cc, dt, d_in, nheads


def _causal_conv(xs, w, b, conv_state=None):
    """Depthwise causal conv. xs (B,S,C); w (W,C). Returns y, new_state (B,W-1,C)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xs.shape[0], W - 1, xs.shape[2]), xs.dtype)
    else:
        pad = conv_state.astype(xs.dtype)
    xp = jnp.concatenate([pad, xs], axis=1)
    y = sum(xp[:, i:i + xs.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, xp.shape[1] - (W - 1):]
    return jax.nn.silu(y), new_state


def mamba2_forward(p, cfg, x, conv_state=None, ssm_state=None, chunk=None):
    """x (B,S,d) -> (y, (conv_state, ssm_state))."""
    B, S, _ = x.shape
    s = cfg.ssm
    z, xin, Bc, Cc, dt, d_in, nheads = _mamba_split(p, cfg, x)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xin, Bc, Cc = jnp.split(conv_out, [d_in, d_in + s.state_dim], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # (B,S,H)
    log_a = -jnp.exp(p["A_log"].astype(jnp.float32)) * dt                # (B,S,H) <= 0
    xh = xin.reshape(B, S, nheads, s.head_dim)
    v = xh * dt[..., None].astype(xh.dtype)                              # dt * x
    k = jnp.broadcast_to(Bc[:, :, None, :], (B, S, nheads, s.state_dim))
    q = jnp.broadcast_to(Cc[:, :, None, :], (B, S, nheads, s.state_dim))

    if S == 1 and ssm_state is not None:
        la0 = jnp.broadcast_to(log_a[:, 0][..., None], k[:, 0].shape)  # (B,H)->(B,H,K)
        y, new_state = recurrence_decode_step(
            ssm_state, q[:, 0], k[:, 0], v[:, 0], la0, include_current=True)
        y = y[:, None]
    else:
        y, new_state = linear_recurrence(
            q, k, v, log_a, include_current=True, initial_state=ssm_state,
            chunk=chunk or s.chunk, decay_per="head")
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"], (new_conv, new_state)


def init_mamba2_state(cfg, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.state_dim
    return (jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
            jnp.zeros((batch, nheads, s.state_dim, s.head_dim), jnp.float32))


# ================================================================ RWKV6 ===

def init_rwkv6(fac: ParamFactory, cfg):
    d, ff, r = cfg.d_model, cfg.d_ff, cfg.rwkv
    H = d // r.head_dim
    names = ("r", "k", "v", "g", "w")
    p = {
        # time-mix ddlerp: x_c = x + (shift(x)-x) * (mu_c + lora)
        "mu": {c: fac.param((d,), ("embed",), init="uniform", scale=0.5) for c in names},
        "mix_A": fac.param((d, 5 * cfg.rwkv.mix_lora), ("embed", None)),
        "mix_B": {c: fac.param((r.mix_lora, d), (None, "embed")) for c in names},
        "wr": fac.param((d, d), ("embed", "heads")),
        "wk": fac.param((d, d), ("embed", "heads")),
        "wv": fac.param((d, d), ("embed", "heads")),
        "wg": fac.param((d, d), ("embed", "heads")),
        "wo": fac.param((d, d), ("heads", "embed")),
        "w0": fac.param((d,), ("embed",), init="constant", scale=-0.6),
        "decay_A": fac.param((d, r.decay_lora), ("embed", None)),
        "decay_B": fac.param((r.decay_lora, d), (None, "embed")),
        "u": fac.param((H, r.head_dim), (None, None), init="uniform", scale=0.5),
        "ln_x": init_group_norm(fac, H, r.head_dim),
        # channel mix
        "cm_mu_k": fac.param((d,), ("embed",), init="uniform", scale=0.5),
        "cm_mu_r": fac.param((d,), ("embed",), init="uniform", scale=0.5),
        "cm_k": fac.param((d, ff), ("embed", "mlp")),
        "cm_v": fac.param((ff, d), ("mlp", "embed")),
        "cm_r": fac.param((d, d), ("embed", "heads")),
    }
    return p


def _token_shift(x, last=None):
    """shift(x)_t = x_{t-1}; last (B,d) is the carry for decode/chunking."""
    B, S, d = x.shape
    first = jnp.zeros((B, 1, d), x.dtype) if last is None else last[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def rwkv6_time_mix(p, cfg, x, shift_state=None, wkv_state=None):
    B, S, d = x.shape
    r_cfg = cfg.rwkv
    H, hd = d // r_cfg.head_dim, r_cfg.head_dim
    xx = _token_shift(x, shift_state) - x
    lora = jnp.tanh(x @ p["mix_A"]).reshape(B, S, 5, r_cfg.mix_lora)
    mixed = {}
    for i, c in enumerate(("r", "k", "v", "g", "w")):
        mu = p["mu"][c] + lora[:, :, i] @ p["mix_B"][c]
        mixed[c] = x + xx * mu
    r = (mixed["r"] @ p["wr"]).reshape(B, S, H, hd)
    k = (mixed["k"] @ p["wk"]).reshape(B, S, H, hd)
    v = (mixed["v"] @ p["wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(mixed["g"] @ p["wg"])
    log_w = -jnp.exp((p["w0"] + jnp.tanh(mixed["w"] @ p["decay_A"]) @ p["decay_B"]
                      ).astype(jnp.float32))                    # (B,S,d) <= 0
    log_a = log_w.reshape(B, S, H, hd)

    if S == 1 and wkv_state is not None:
        y, new_wkv = recurrence_decode_step(
            wkv_state, r[:, 0], k[:, 0], v[:, 0], log_a[:, 0], u=p["u"],
            include_current=False)
        y = y[:, None]
    else:
        y, new_wkv = linear_recurrence(
            r, k, v, log_a, u=p["u"], include_current=False,
            initial_state=wkv_state, chunk=r_cfg.chunk, decay_per="dim")
    y = apply_group_norm(p["ln_x"], y).reshape(B, S, d)
    y = (y * g) @ p["wo"]
    return y, (x[:, -1], new_wkv)


def rwkv6_channel_mix(p, x, shift_state=None):
    xx = _token_shift(x, shift_state) - x
    xk = x + xx * p["cm_mu_k"]
    xr = x + xx * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_k"]))
    return jax.nn.sigmoid(xr @ p["cm_r"]) * (kk @ p["cm_v"]), x[:, -1]


def init_rwkv6_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    H, hd = d // cfg.rwkv.head_dim, cfg.rwkv.head_dim
    return (jnp.zeros((batch, d), dtype),                        # tm shift
            jnp.zeros((batch, H, hd, hd), jnp.float32),          # wkv state
            jnp.zeros((batch, d), dtype))                        # cm shift
