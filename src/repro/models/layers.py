"""Basic neural-net layers: norms, RoPE, MLPs, embeddings.

All layers are pure functions over params-dicts created by a ParamFactory.
Logical sharding axes used here:
  "embed"  — d_model dim           (rule: -> data axis, FSDP-style)
  "mlp"    — d_ff dim              (rule: -> model axis, tensor parallel)
  "vocab"  — vocabulary dim        (rule: -> model axis)
  "heads"  — fused num_heads*head_dim   (rule: -> model axis)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.factory import ParamFactory


# ---------------------------------------------------------------- norms ---

def init_norm(fac: ParamFactory, d: int, kind: str, use_bias: bool):
    p = {"scale": fac.param((d,), ("embed",), init="ones")}
    if kind == "layernorm" and use_bias:
        p["bias"] = fac.param((d,), ("embed",), init="zeros")
    return p


def apply_norm(p, x, kind: str, eps: float):
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_normalize(x, eps: float = 1e-6):
    """Scale-free RMS normalisation (used by qk-norm with its own scale)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def init_group_norm(fac: ParamFactory, heads: int, head_dim: int):
    return {"scale": fac.param((heads, head_dim), (None, None), init="ones"),
            "bias": fac.param((heads, head_dim), (None, None), init="zeros")}


def apply_group_norm(p, x, eps: float = 64e-5):
    """Per-head LayerNorm over head_dim, x: (..., H, hd). (RWKV ln_x)"""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ----------------------------------------------------------------- rope ---

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos,sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd) (llama-style non-interleaved halves); positions (B,S) or (S,)."""
    hd = x.shape[-1]
    cos, sin = rope_angles(positions, hd, theta)  # (B,S,half) or (S,half)
    if cos.ndim == 2:  # (S, half) -> broadcast batch
        cos, sin = cos[None], sin[None]
    cos, sin = cos[..., None, :], sin[..., None, :]  # head axis
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ mlp ---

def init_mlp(fac: ParamFactory, d: int, d_ff: int, activation: str, use_bias: bool):
    p = {}
    if activation == "silu":  # SwiGLU
        p["w_gate"] = fac.param((d, d_ff), ("embed", "mlp"))
        p["w_up"] = fac.param((d, d_ff), ("embed", "mlp"))
    else:
        p["w_up"] = fac.param((d, d_ff), ("embed", "mlp"))
        if use_bias:
            p["b_up"] = fac.param((d_ff,), ("mlp",), init="zeros")
    p["w_down"] = fac.param((d_ff, d), ("mlp", "embed"))
    if use_bias:
        p["b_down"] = fac.param((d,), ("embed",), init="zeros")
    return p


def apply_mlp(p, x, activation: str):
    if activation == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ----------------------------------------------------------- embeddings ---

def init_embedding(fac: ParamFactory, vocab: int, d: int):
    return {"table": fac.param((vocab, d), ("vocab", "embed"), init="normal", scale=0.02)}


def embed_tokens(p, tokens):
    return p["table"][tokens]


def unembed(p_out, x, tied_table=None):
    """Final logits projection. p_out holds 'w' unless embeddings are tied."""
    w = tied_table.T if tied_table is not None else p_out["w"]
    return x @ w


def init_unembed(fac: ParamFactory, d: int, vocab: int):
    return {"w": fac.param((d, vocab), ("embed", "vocab"), init="normal", scale=0.02)}
