"""Attention layers: GQA (full / sliding-window / chunked-memory-efficient)
and MLA (multi-head latent attention, MiniCPM3/DeepSeek-V2 style), with
decode paths against a KV cache.

Shapes: x (B, S, D); q (B, S, H, hd); k/v (B, S, KV, hd).
KV caches: GQA -> {"k": (B, C, KV, hd), "v": ..., "pos": ()} where C is the
cache length (seq_len, or the sliding window for long-context serving).
MLA -> compressed cache {"ckv": (B, C, kv_lora), "krope": (B, C, rope_dim)}.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.factory import ParamFactory
from repro.models.layers import apply_rope, rms_normalize

NEG_INF = -1e30


# ================================================================== GQA ===

def init_attention(fac: ParamFactory, cfg):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": fac.param((d, H * hd), ("embed", "heads")),
        "wk": fac.param((d, KV * hd), ("embed", "heads")),
        "wv": fac.param((d, KV * hd), ("embed", "heads")),
        "wo": fac.param((H * hd, d), ("heads", "embed")),
    }
    if cfg.use_bias:
        p["bq"] = fac.param((H * hd,), ("heads",), init="zeros")
        p["bk"] = fac.param((KV * hd,), ("heads",), init="zeros")
        p["bv"] = fac.param((KV * hd,), ("heads",), init="zeros")
        p["bo"] = fac.param((d,), ("embed",), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = fac.param((hd,), (None,), init="ones")
        p["k_norm"] = fac.param((hd,), (None,), init="ones")
    return p


def _project_qkv(p, cfg, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_normalize(q) * p["q_norm"]
        k = rms_normalize(k) * p["k_norm"]
    return q, k, v


def _out_proj(p, attn_out):
    B, S = attn_out.shape[:2]
    y = attn_out.reshape(B, S, -1) @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y


def _causal_scores_mask(q_pos, k_pos, window: Optional[int]):
    """(..., Sq, Sk) boolean mask: True = attend."""
    m = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return m


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd) grouped-query attention, fp32 softmax."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, hd)


def attention_forward(p, cfg, x, positions, *, window: Optional[int] = None,
                      q_chunk: Optional[int] = None, kv_override=None,
                      return_kv: bool = False):
    """Training/prefill causal self-attention.

    q_chunk: if set and S > q_chunk, use the memory-efficient chunked path
    (lax.scan over query blocks, rematerialised) so the S x S score matrix
    is never fully materialised.
    kv_override: (k, v) pair for cross-attention (positions then index q only).
    return_kv: also return the post-rope (k, v) — used by the batched
    prefill path to fill the decode cache in one pass.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    scale = 1.0 / (cfg.head_dim ** 0.5)
    cross = kv_override is not None
    if cross:
        k, v = kv_override
        q = q  # no rope on cross-attention
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    def out(y):
        return (y, (k, v)) if return_kv else y

    Sk = k.shape[1]
    if q_chunk is None or S <= q_chunk:
        if cross:
            mask = jnp.ones((B, S, Sk), dtype=bool)
        else:
            mask = _causal_scores_mask(positions[None] if positions.ndim == 1 else positions,
                                       positions[None] if positions.ndim == 1 else positions,
                                       window)
            if mask.shape[0] == 1:
                mask = jnp.broadcast_to(mask, (B, S, Sk))
        o = _sdpa(q, k, v, mask, scale)
        return out(_out_proj(p, o))

    # ---- chunked path: scan over query blocks --------------------------
    assert not cross, "chunked path is for causal self-attention"
    assert S % q_chunk == 0, (S, q_chunk)
    nq = S // q_chunk
    pos = positions if positions.ndim == 1 else positions[0]
    qb = q.reshape(B, nq, q_chunk, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
    posb = pos.reshape(nq, q_chunk)

    @jax.checkpoint
    def body(carry, inp):
        qc, pc = inp  # (B, q_chunk, H, hd), (q_chunk,)
        mask = _causal_scores_mask(pc, pos, window)  # (q_chunk, Sk)
        mask = jnp.broadcast_to(mask[None], (B, q_chunk, Sk))
        oc = _sdpa(qc, k, v, mask, scale)
        return carry, oc

    _, ob = jax.lax.scan(body, (), (qb, posb))
    o = ob.transpose(1, 0, 2, 3, 4).reshape(B, S, q.shape[2], q.shape[3])
    return out(_out_proj(p, o))


def init_attn_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
    }


def attention_decode(p, cfg, x, cache, pos, *, window: Optional[int] = None):
    """Single-token decode. x (B, 1, D); pos scalar int32 (current index).

    The cache holds `cache_len` slots; with a sliding window the slot is
    pos % cache_len (rotating buffer), and positions for RoPE/masking are
    reconstructed from pos.  Returns (y, new_cache).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)
    q = apply_rope(q, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
    k = apply_rope(k, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)

    C = cache["k"].shape[1]
    slot = pos % C if window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))

    # effective absolute position of each cache slot
    idx = jnp.arange(C, dtype=jnp.int32)
    if window is not None:
        # rotating buffer: slot i holds the largest t <= pos with t % C == i
        turn = (pos // C) * C + idx
        k_pos = jnp.where(turn > pos, turn - C, turn)
        valid = (k_pos >= 0) & (k_pos >= pos - (window - 1)) & (k_pos <= pos)
    else:
        k_pos = idx
        valid = idx <= pos

    scale = 1.0 / (cfg.head_dim ** 0.5)
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, ck.astype(q.dtype)).astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cv)
    y = _out_proj(p, out.reshape(B, 1, H * hd)[:, :, :].reshape(B, 1, H, hd))
    return y, {"k": ck, "v": cv}


# ================================================================== MLA ===

def init_mla(fac: ParamFactory, cfg):
    d, H = cfg.d_model, cfg.num_heads
    m = cfg.mla
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": fac.param((d, m.q_lora_rank), ("embed", "qlora")),
        "q_norm": fac.param((m.q_lora_rank,), (None,), init="ones"),
        "wq_b": fac.param((m.q_lora_rank, H * qk_head), ("qlora", "heads")),
        "wkv_a": fac.param((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", None)),
        "kv_norm": fac.param((m.kv_lora_rank,), (None,), init="ones"),
        "wkv_b": fac.param((m.kv_lora_rank, H * (m.qk_nope_head_dim + m.v_head_dim)),
                           ("kvlora", "heads")),
        "wo": fac.param((H * m.v_head_dim, d), ("heads", "embed")),
    }


def _mla_q(p, cfg, x):
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ql = rms_normalize(x @ p["wq_a"]) * p["q_norm"]
    q = (ql @ p["wq_b"]).reshape(B, S, H, qk_head)
    return jnp.split(q, [m.qk_nope_head_dim], axis=-1)  # q_nope, q_rope


def _mla_ckv(p, cfg, x):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    ckv, krope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rms_normalize(ckv) * p["kv_norm"]
    return ckv, krope


def mla_forward(p, cfg, x, positions, *, q_chunk: Optional[int] = None,
                return_ckv: bool = False):
    """Training/prefill MLA (expanded form).  return_ckv also returns the
    compressed (ckv, roped krope) pair for decode-cache prefill."""
    B, S, _ = x.shape
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x)
    ckv, krope = _mla_ckv(p, cfg, x)
    kvb = (ckv @ p["wkv_b"]).reshape(B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)

    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    krope = apply_rope(krope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rope)
    cache_kv = (ckv, krope[:, :, 0, :])  # compressed decode-cache contents

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(krope, (*k_nope.shape[:3], m.qk_rope_head_dim))],
                        axis=-1)
    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)

    pos = positions if positions.ndim == 1 else positions[0]
    if q_chunk is None or S <= q_chunk:
        mask = _causal_scores_mask(pos, pos, None)[None]
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    else:
        nq = S // q_chunk
        qb = q.reshape(B, nq, q_chunk, H, -1).transpose(1, 0, 2, 3, 4)
        posb = pos.reshape(nq, q_chunk)

        @jax.checkpoint
        def body(carry, inp):
            qc, pc = inp
            mask = _causal_scores_mask(pc, pos, None)[None, None]
            sc = jnp.einsum("bqhd,bshd->bhqs", qc, k).astype(jnp.float32) * scale
            sc = jnp.where(mask, sc, NEG_INF)
            pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
            return carry, jnp.einsum("bhqs,bshd->bqhd", pr, v)

        _, ob = jax.lax.scan(body, (), (qb, posb))
        out = ob.transpose(1, 0, 2, 3, 4).reshape(B, S, H, m.v_head_dim)

    y = out.reshape(B, S, -1) @ p["wo"]
    return (y, cache_kv) if return_ckv else y


def init_mla_cache(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p, cfg, x, cache, pos):
    """Single-token MLA decode using the *absorbed* formulation: attention is
    computed directly in the compressed kv_lora space, so the cache stays
    (C, kv_lora + rope) per token — MLA's memory advantage."""
    B = x.shape[0]
    m, H = cfg.mla, cfg.num_heads
    q_nope, q_rope = _mla_q(p, cfg, x)            # (B,1,H,nope),(B,1,H,rope)
    ckv_new, krope_new = _mla_ckv(p, cfg, x)      # (B,1,kvl),(B,1,rope)
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q_rope = apply_rope(q_rope, pos_arr, cfg.rope_theta)
    krope_new = apply_rope(krope_new[:, :, None, :], pos_arr, cfg.rope_theta)[:, :, 0, :]

    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krp = jax.lax.dynamic_update_slice(cache["krope"], krope_new.astype(cache["krope"].dtype), (0, pos, 0))

    # absorb W_kv_b: split into K-part (kvl, H, nope) and V-part (kvl, H, vdim)
    wkvb = p["wkv_b"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    wk, wv = jnp.split(wkvb, [m.qk_nope_head_dim], axis=-1)
    # q_nope -> compressed space: (B,1,H,kvl)
    qc = jnp.einsum("bqhn,chn->bqhc", q_nope, wk)
    C = ckv.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)
    valid = idx <= pos
    scale = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    scores = (jnp.einsum("bqhc,bsc->bhqs", qc, ckv.astype(qc.dtype))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope, krp.astype(q_rope.dtype)))
    scores = scores.astype(jnp.float32) * scale
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    out_c = jnp.einsum("bhqs,bsc->bqhc", probs, ckv)          # (B,1,H,kvl)
    out = jnp.einsum("bqhc,chv->bqhv", out_c.astype(wv.dtype), wv)  # (B,1,H,vdim)
    y = out.reshape(B, 1, -1) @ p["wo"]
    return y, {"ckv": ckv, "krope": krp}
