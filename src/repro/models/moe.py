"""Mixture-of-Experts layer: top-k softmax router + expert FFNs.

Two dispatch strategies, selectable per-call (used by the perf hillclimb):

* ``einsum`` — classic GShard/Switch capacity-based one-hot dispatch.
  Tokens are processed in groups; each group builds a (g, E, C) one-hot
  dispatch tensor contracted against activations.  Simple, GSPMD-friendly,
  but the dispatch einsums cost O(g*E*C*d) MXU FLOPs.
* ``sort`` — argsort-based dispatch: tokens are sorted by expert id and
  scattered into the (E, C, d) buffer with pure data movement (gather/
  scatter), so HLO FLOPs ≈ expert FFN FLOPs only.

Both drop overflow tokens beyond per-expert capacity C (the classic
capacity-factor contract); the router uses softmax-then-top-k with
renormalised weights and a Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.factory import ParamFactory


def init_moe(fac: ParamFactory, cfg):
    d, m = cfg.d_model, cfg.moe
    E, f = m.num_experts, m.d_ff_expert
    d_ax = "embed" if m.shard_expert_dmodel else None
    p = {
        # expert weights: expert-parallel over "model" when E divides the
        # axis, otherwise the per-expert ff dim takes it (spec_for dedupes
        # the mesh axis); d_model dim optionally FSDP-sharded over "data"
        # (see MoEConfig.shard_expert_dmodel)
        "router": fac.param((d, E), ("embed", None), init="normal", scale=0.02),
        "w_gate": fac.param((E, d, f), ("expert", d_ax, "mlp")),
        "w_up": fac.param((E, d, f), ("expert", d_ax, "mlp")),
        "w_down": fac.param((E, f, d), ("expert", "mlp", d_ax)),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p["shared"] = {
            "w_gate": fac.param((d, fs), ("embed", "mlp")),
            "w_up": fac.param((d, fs), ("embed", "mlp")),
            "w_down": fac.param((fs, d), ("mlp", "embed")),
        }
    return p


def _expert_ffn(p, xe):
    """xe: (E, C, d) -> (E, C, d), vmapped SwiGLU over experts."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _route(p, cfg, x2d):
    """x2d (T, d) -> (weights (T,k), ids (T,k), aux_loss)."""
    m = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    w, ids = jax.lax.top_k(probs, m.top_k)                      # (T, k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance loss: E * sum_e f_e * P_e
    T = x2d.shape[0]
    onehot = jax.nn.one_hot(ids[:, 0], m.num_experts, dtype=jnp.float32)
    f_e = jnp.mean(onehot, axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(f_e * P_e)
    return w.astype(x2d.dtype), ids, aux


def _capacity(group: int, cfg) -> int:
    """Per-expert slot budget: capacity factor 1.25 at scale; small groups
    (decode steps, smoke tests) get full capacity so nothing drops where
    dropping would be a correctness surprise rather than a throughput
    trade-off."""
    m = cfg.moe
    c = int(group * m.top_k * 1.25 / m.num_experts) + 1
    return max(min(group, max(c, 16)), 1)


def moe_forward_einsum(p, cfg, x, group: int = 2048):
    """GShard-style grouped one-hot dispatch."""
    B, S, d = x.shape
    m = cfg.moe
    T = B * S
    g = min(group, T)
    assert T % g == 0, (T, g)
    G = T // g
    C = _capacity(g, cfg)
    xg = x.reshape(G, g, d)
    w, ids, aux = _route(p, cfg, x.reshape(T, d))
    w = w.reshape(G, g, m.top_k)
    ids = ids.reshape(G, g, m.top_k)

    # position of each (token, k) inside its expert queue
    oh = jax.nn.one_hot(ids, m.num_experts, dtype=jnp.int32)    # (G,g,k,E)
    ohf = oh.reshape(G, g * m.top_k, m.num_experts)
    pos = jnp.cumsum(ohf, axis=1) - ohf                         # (G,g*k,E)
    pos = pos.reshape(G, g, m.top_k, m.num_experts)
    slot = jnp.sum(pos * oh, axis=-1)                           # (G,g,k)
    keep = slot < C
    # dispatch tensor (G, g, E, C): one-hot over (expert, slot)
    disp = (oh[..., None] * jax.nn.one_hot(slot, C, dtype=x.dtype)[..., None, :]
            * keep[..., None, None].astype(x.dtype))            # (G,g,k,E,C)
    disp_tok = jnp.sum(disp, axis=2)                            # (G,g,E,C)
    combine = jnp.sum(disp * w[..., None, None].astype(x.dtype), axis=2)

    xe = jnp.einsum("gtec,gtd->gecd", disp_tok, xg)             # (G,E,C,d)
    ye = jax.vmap(lambda xs: _expert_ffn(p, xs))(xe)            # (G,E,C,d)
    y = jnp.einsum("gtec,gecd->gtd", combine, ye)
    y = y.reshape(B, S, d)
    if m.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return y, aux


def moe_forward_sort(p, cfg, x, group: int = 2048):
    """Sort-based dispatch, GROUP-LOCAL: tokens are sorted by expert id
    *within* fixed-size groups, so every index is group-relative and the
    leading group axis keeps the batch's data-parallel sharding (a global
    argsort/gather makes GSPMD replicate the whole token buffer across the
    mesh — measured 7x worse; see EXPERIMENTS.md §Perf iteration 1).
    Dispatch is pure data movement (sort + one-hot-free scatter/gather);
    MXU FLOPs ≈ expert FFN only."""
    B, S, d = x.shape
    m = cfg.moe
    T = B * S
    g = min(group, T)
    assert T % g == 0, (T, g)
    G = T // g
    C = _capacity(g, cfg)
    E = m.num_experts
    xg = x.reshape(G, g, d)
    w, ids, aux = _route(p, cfg, x.reshape(T, d))
    w = w.reshape(G, g * m.top_k)
    ids = ids.reshape(G, g * m.top_k)

    order = jnp.argsort(ids, axis=1)                            # per-group sort
    tok_of = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(g * m.top_k)[None] // m.top_k,
                         (G, g * m.top_k)), order, axis=1)      # (G, g*k)
    eid_sorted = jnp.take_along_axis(ids, order, axis=1)
    # slot within (group, expert): position among same-expert entries
    oh = jax.nn.one_hot(ids, E, dtype=jnp.int32)                # (G, g*k, E)
    pos = jnp.cumsum(oh, axis=1) - oh
    slot = jnp.take_along_axis(
        pos.reshape(G, g * m.top_k, E),
        ids[..., None], axis=2)[..., 0]                         # (G, g*k)
    slot_sorted = jnp.take_along_axis(slot, order, axis=1)
    keep = slot_sorted < C
    dest = eid_sorted * C + jnp.where(keep, slot_sorted, 0)     # (G, g*k)

    xs = jnp.take_along_axis(xg, tok_of[..., None], axis=1)     # (G, g*k, d)
    xs = jnp.where(keep[..., None], xs, 0.0)
    buf = jnp.zeros((G, E * C, d), x.dtype)
    buf = jax.vmap(lambda b, dst, v: b.at[dst].add(v))(buf, dest, xs)
    ye = jax.vmap(lambda xe: _expert_ffn(p, xe.reshape(E, C, d)))(buf)
    out = jnp.take_along_axis(ye.reshape(G, E * C, d), dest[..., None], axis=1)
    out = jnp.where(keep[..., None], out, 0.0)
    w_sorted = jnp.take_along_axis(w, order, axis=1)
    contrib = out * w_sorted[..., None].astype(x.dtype)
    y = jnp.zeros((G, g, d), x.dtype)
    y = jax.vmap(lambda yy, t, c: yy.at[t].add(c))(y, tok_of, contrib)
    y = y.reshape(B, S, d)
    if m.num_shared_experts:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])) @ sp["w_down"]
    return y, aux


def moe_forward(p, cfg, x, dispatch: str = "einsum", group: int = 2048):
    if dispatch == "einsum":
        return moe_forward_einsum(p, cfg, x, group)
    if dispatch == "sort":
        return moe_forward_sort(p, cfg, x, group)
    raise ValueError(dispatch)
