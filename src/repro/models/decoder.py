"""Composable decoder stack covering all architecture families in the zoo:

  dense GQA (llama/mistral/starcoder2/command-r style), MLA (MiniCPM3),
  MoE (granite/qwen3-moe), Mamba2 hybrid with shared attention (Zamba2),
  RWKV6, enc-dec (Whisper), and stub-frontend VLM/audio wrappers.

Layers are grouped into maximal runs of identical block type and executed
with ``lax.scan`` over stacked parameters — one traced body per run keeps
HLO size (and GSPMD compile time) independent of depth.

Public API (all pure functions of (cfg, params, ...)):
  init_params / abstract_params
  forward(cfg, params, tokens, ...)        -> logits, aux
  loss_fn(cfg, params, batch)              -> loss, metrics
  init_cache(cfg, params, batch, cache_len, [encoder_embeds])
  decode_step(cfg, params, cache, token, pos) -> logits, cache
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import recurrence as rec
from repro.models.factory import AbstractParam, ParamFactory, is_abstract_leaf
from repro.models.layers import (apply_mlp, apply_norm, embed_tokens,
                                 init_embedding, init_mlp, init_norm,
                                 init_unembed, unembed)


# ------------------------------------------------------------- grouping ---

def layer_tags(cfg):
    return tuple((kind, cfg.is_moe_layer(i)) for i, kind in enumerate(cfg.pattern()))


def layer_groups(cfg):
    """Run-length encoding of layer tags -> ((tag, count), ...)."""
    tags = layer_tags(cfg)
    groups = []
    for t in tags:
        if groups and groups[-1][0] == t:
            groups[-1][1] += 1
        else:
            groups.append([t, 1])
    return tuple((t, c) for t, c in groups)


# ----------------------------------------------------------------- init ---

def _init_layer(fac, cfg, tag, cross: bool):
    kind, is_moe = tag
    p = {"norm1": init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias)}
    if kind == "attn":
        p["attn"] = attn.init_mla(fac, cfg) if cfg.attention == "mla" else attn.init_attention(fac, cfg)
    elif kind == "shared_attn":
        pass  # weights live at top level
    elif kind == "mamba2":
        p["mamba"] = rec.init_mamba2(fac, cfg)
        return p
    elif kind == "rwkv6":
        p["tm"] = rec.init_rwkv6(fac, cfg)
        p["norm2"] = init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias)
        return p
    else:
        raise ValueError(kind)
    if cross:
        p["cross_norm"] = init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias)
        p["cross_attn"] = attn.init_attention(fac, cfg)
    if not cfg.parallel_block:
        p["norm2"] = init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias)
    if is_moe:
        p["moe"] = moe_lib.init_moe(fac, cfg)
    else:
        p["mlp"] = init_mlp(fac, cfg.d_model, cfg.d_ff, cfg.activation, cfg.use_bias)
    return p


def _stack_layers(fac, cfg, tag, count, cross):
    if fac.abstract:
        one = _init_layer(fac, cfg, tag, cross)
        return jax.tree.map(
            lambda a: AbstractParam((count,) + a.shape, (None,) + a.axes, a.dtype),
            one, is_leaf=is_abstract_leaf)
    layers = [_init_layer(fac, cfg, tag, cross) for _ in range(count)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _build_params(fac, cfg):
    cross = cfg.encoder is not None
    params = {
        "embed": init_embedding(fac, cfg.padded_vocab(), cfg.d_model),
        "groups": [_stack_layers(fac, cfg, tag, count, cross)
                   for tag, count in layer_groups(cfg)],
        "final_norm": init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_unembed(fac, cfg.d_model, cfg.padded_vocab())
    if any(k == "shared_attn" for k in cfg.pattern()):
        params["shared_attn"] = attn.init_attention(fac, cfg)
    if cfg.encoder is not None:
        enc_tag = ("attn", False)
        params["encoder"] = {
            "groups": [_stack_layers(fac, cfg, enc_tag, cfg.encoder.num_layers, False)],
            "final_norm": init_norm(fac, cfg.d_model, cfg.norm, cfg.use_bias),
        }
    return params


def init_params(cfg, key):
    dtype = jnp.dtype(cfg.param_dtype)
    return _build_params(ParamFactory(key=key, dtype=dtype), cfg)


def abstract_params(cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    return _build_params(ParamFactory(abstract=True, dtype=dtype), cfg)


# -------------------------------------------------------------- forward ---

def _cast_params(cfg, params):
    """Cast float params to the compute dtype (master copies stay fp32 in the
    optimizer; this is the standard bf16-compute cast, fused away by XLA).
    fp32-sensitive code paths (norms, softmax, recurrence states) upcast
    internally."""
    ct = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda x: x.astype(ct) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


def _mask_padded_vocab(cfg, logits):
    """Padded vocab columns (sharding-only rows) must never win softmax/argmax."""
    Vp, V = cfg.padded_vocab(), cfg.vocab_size
    if Vp == V:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < V, logits, jnp.asarray(-1e30, logits.dtype))

def _residual_scale(cfg):
    if cfg.scale_depth is None:
        return 1.0
    return cfg.scale_depth / (cfg.num_layers ** 0.5)


def _apply_layer(cfg, lp, shared, x, positions, tag, *, enc_out=None,
                 q_chunk=None, moe_dispatch="einsum", window=None):
    """One layer forward (training/prefill). Returns (x, aux_loss)."""
    kind, is_moe = tag
    rs = _residual_scale(cfg)
    aux = jnp.float32(0.0)
    if kind == "mamba2":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, _ = rec.mamba2_forward(lp["mamba"], cfg, h)
        return x + y * rs, aux
    if kind == "rwkv6":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, _ = rec.rwkv6_time_mix(lp["tm"], cfg, h)
        x = x + y * rs
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, _ = rec.rwkv6_channel_mix(lp["tm"], h)
        return x + y * rs, aux

    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    ap = shared if kind == "shared_attn" else lp["attn"]
    if cfg.attention == "mla" and kind == "attn":
        a = attn.mla_forward(ap, cfg, h, positions, q_chunk=q_chunk)
    else:
        a = attn.attention_forward(ap, cfg, h, positions, window=window, q_chunk=q_chunk)
    if cfg.parallel_block:
        m = apply_mlp(lp["mlp"], h, cfg.activation)
        return x + (a + m) * rs, aux
    x = x + a * rs
    if enc_out is not None:
        h = apply_norm(lp["cross_norm"], x, cfg.norm, cfg.norm_eps)
        kc, vc = _cross_kv(lp["cross_attn"], cfg, enc_out)
        c = attn.attention_forward(lp["cross_attn"], cfg, h, positions, kv_override=(kc, vc))
        x = x + c * rs
    h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if is_moe:
        y, aux = moe_lib.moe_forward(lp["moe"], cfg, h, dispatch=moe_dispatch)
    else:
        y = apply_mlp(lp["mlp"], h, cfg.activation)
    return x + y * rs, aux


def _cross_kv(ap, cfg, enc_out):
    B, S, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ ap["wk"]).reshape(B, S, KV, hd)
    v = (enc_out @ ap["wv"]).reshape(B, S, KV, hd)
    if "bk" in ap:
        k = k + ap["bk"].reshape(KV, hd)
        v = v + ap["bv"].reshape(KV, hd)
    return k, v


def _scan_group(cfg, gp, tag, x, positions, shared, *, enc_out, q_chunk,
                moe_dispatch, window, remat):
    def body(carry, lp):
        h, aux = carry
        h2, a = _apply_layer(cfg, lp, shared, h, positions, tag, enc_out=enc_out,
                             q_chunk=q_chunk, moe_dispatch=moe_dispatch, window=window)
        return (h2, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), gp)
    return x, aux


def forward(cfg, params, tokens, *, prefix_embeds=None, encoder_embeds=None,
            q_chunk: Optional[int] = None, moe_dispatch: str = "einsum",
            remat: bool = True):
    """tokens (B, S_tok). prefix_embeds (B, P, d) are prepended (VLM stub).
    encoder_embeds (B, F, d) feed the encoder tower (audio stub).
    Returns (logits (B, S_total, V), aux_losses)."""
    params = _cast_params(cfg, params)
    x = embed_tokens(params["embed"], tokens) * cfg.scale_emb
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    enc_out = None
    if cfg.encoder is not None:
        assert encoder_embeds is not None
        e = encoder_embeds.astype(x.dtype)
        e_pos = jnp.arange(e.shape[1], dtype=jnp.int32)
        for gp in params["encoder"]["groups"]:
            def ebody(carry, lp):
                h = apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
                a = _enc_self_attn(lp["attn"], cfg, h, e_pos)
                h2 = carry + a
                hh = apply_norm(lp["norm2"], h2, cfg.norm, cfg.norm_eps)
                return h2 + apply_mlp(lp["mlp"], hh, cfg.activation), None
            if remat:
                ebody = jax.checkpoint(ebody)
            e, _ = jax.lax.scan(ebody, e, gp)
        enc_out = apply_norm(params["encoder"]["final_norm"], e, cfg.norm, cfg.norm_eps)

    aux_total = jnp.float32(0.0)
    shared = params.get("shared_attn")
    for gp, (tag, count) in zip(params["groups"], layer_groups(cfg)):
        x, aux = _scan_group(cfg, gp, tag, x, positions, shared, enc_out=enc_out,
                             q_chunk=q_chunk, moe_dispatch=moe_dispatch,
                             window=cfg.sliding_window, remat=remat)
        aux_total = aux_total + aux

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), x, tied_table=tied) * cfg.logits_scale
    logits = _mask_padded_vocab(cfg, logits)
    return logits, aux_total


def _enc_self_attn(ap, cfg, x, positions):
    """Non-causal encoder self-attention (no rope — stub embeddings carry
    positional info; whisper uses sinusoidal added upstream)."""
    B, S, _ = x.shape
    q, k, v = attn._project_qkv(ap, cfg, x)
    scale = 1.0 / (cfg.head_dim ** 0.5)
    mask = jnp.ones((B, S, S), bool)
    out = attn._sdpa(q, k, v, mask, scale)
    return attn._out_proj(ap, out)


# ----------------------------------------------------------------- loss ---

def loss_fn(cfg, params, batch, *, q_chunk=None, moe_dispatch="einsum", remat=True):
    """batch: {"tokens": (B,S), "labels": (B,S) with -1 = masked,
    optional "prefix_embeds"/"encoder_embeds"}."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          prefix_embeds=batch.get("prefix_embeds"),
                          encoder_embeds=batch.get("encoder_embeds"),
                          q_chunk=q_chunk, moe_dispatch=moe_dispatch, remat=remat)
    labels = batch["labels"]
    P = logits.shape[1] - labels.shape[1]
    if P:  # prefix positions carry no loss
        logits = logits[:, P:]
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_coef * aux
    return loss, {"nll": loss, "aux": aux}


# -------------------------------------------------------------- prefill ---

def _pack_rotating(t, alen, dtype):
    """t (B, S, ...) -> rotating cache buffer (B, alen, ...): slot p%alen
    holds the latest position p (matches attention_decode's layout)."""
    B, S = t.shape[:2]
    buf = jnp.zeros((B, alen) + t.shape[2:], dtype)
    take = min(S, alen)
    tail = t[:, S - take:]
    slots = (jnp.arange(S - take, S)) % alen
    return buf.at[:, slots].set(tail.astype(dtype))


def _apply_layer_prefill(cfg, lp, shared, x, positions, tag, cache_len, *,
                         enc_out=None, q_chunk=None, moe_dispatch="einsum",
                         cache_dtype=jnp.bfloat16):
    """Like _apply_layer, but also emits this layer's filled decode cache."""
    kind, is_moe = tag
    rs = _residual_scale(cfg)
    if kind == "mamba2":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, (conv, ssm) = rec.mamba2_forward(lp["mamba"], cfg, h)
        return x + y * rs, {"conv": conv, "ssm": ssm}
    if kind == "rwkv6":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, (sh, wkv) = rec.rwkv6_time_mix(lp["tm"], cfg, h)
        x = x + y * rs
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, cm_sh = rec.rwkv6_channel_mix(lp["tm"], h)
        return x + y * rs, {"tm_shift": sh, "wkv": wkv, "cm_shift": cm_sh}

    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    ap = shared if kind == "shared_attn" else lp["attn"]
    window = cfg.serve_window
    alen = min(cache_len, window) if window else cache_len
    if cfg.attention == "mla" and kind == "attn":
        a, (ckv, krope) = attn.mla_forward(ap, cfg, h, positions,
                                           q_chunk=q_chunk, return_ckv=True)
        # MLA cache is always full-length (compressed)
        S = ckv.shape[1]
        lcache = {
            "ckv": jnp.zeros((x.shape[0], cache_len, ckv.shape[-1]),
                             cache_dtype).at[:, :S].set(ckv.astype(cache_dtype)),
            "krope": jnp.zeros((x.shape[0], cache_len, krope.shape[-1]),
                               cache_dtype).at[:, :S].set(krope.astype(cache_dtype)),
        }
    else:
        a, (k, v) = attn.attention_forward(
            ap, cfg, h, positions, window=cfg.sliding_window, q_chunk=q_chunk,
            return_kv=True)
        lcache = {"k": _pack_rotating(k, alen, cache_dtype),
                  "v": _pack_rotating(v, alen, cache_dtype)}
    if cfg.parallel_block:
        m = apply_mlp(lp["mlp"], h, cfg.activation)
        return x + (a + m) * rs, lcache
    x = x + a * rs
    if enc_out is not None:
        h = apply_norm(lp["cross_norm"], x, cfg.norm, cfg.norm_eps)
        kc, vc = _cross_kv(lp["cross_attn"], cfg, enc_out)
        c = attn.attention_forward(lp["cross_attn"], cfg, h, positions,
                                   kv_override=(kc, vc))
        x = x + c * rs
    h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if is_moe:
        y, _ = moe_lib.moe_forward(lp["moe"], cfg, h, dispatch=moe_dispatch)
    else:
        y = apply_mlp(lp["mlp"], h, cfg.activation)
    return x + y * rs, lcache


def prefill(cfg, params, tokens, cache_len: int, *, prefix_embeds=None,
            encoder_embeds=None, q_chunk=None, moe_dispatch: str = "einsum",
            cache_dtype=jnp.bfloat16):
    """Batched prompt processing: one forward pass that returns
    (last_position_logits, filled_cache, next_pos).  ~S times faster than
    stepping decode_step over the prompt; exact same cache contents
    (tests/test_prefill.py)."""
    params = _cast_params(cfg, params)
    x = embed_tokens(params["embed"], tokens) * cfg.scale_emb
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    enc_out = None
    if cfg.encoder is not None:
        assert encoder_embeds is not None
        enc_out = _encode(cfg, params, encoder_embeds)

    shared = params.get("shared_attn")
    caches = []
    for gp, (tag, count) in zip(params["groups"], layer_groups(cfg)):
        def body(carry, lp):
            h, = carry
            h2, lc = _apply_layer_prefill(cfg, lp, shared, h, positions, tag,
                                          cache_len, enc_out=enc_out,
                                          q_chunk=q_chunk,
                                          moe_dispatch=moe_dispatch,
                                          cache_dtype=cache_dtype)
            return (h2,), lc

        (x,), gcache = jax.lax.scan(body, (x,), gp)
        caches.append(gcache)

    cache = {"groups": caches}
    if cfg.encoder is not None:
        cross = []
        for gp in params["groups"]:
            ks, vs = jax.vmap(lambda lp: _cross_kv(lp["cross_attn"], cfg, enc_out))(gp)
            cross.append({"k": ks.astype(cache_dtype), "v": vs.astype(cache_dtype)})
        cache["cross"] = cross

    xl = apply_norm(params["final_norm"], x[:, -1:], cfg.norm, cfg.norm_eps)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), xl, tied_table=tied) * cfg.logits_scale
    logits = _mask_padded_vocab(cfg, logits)
    return logits, cache, jnp.int32(S)


# --------------------------------------------------------------- decode ---

def init_cache(cfg, params, batch: int, cache_len: int, *, encoder_embeds=None,
               dtype=jnp.bfloat16):
    """Build the per-group stacked cache pytree."""
    window = cfg.serve_window
    alen = min(cache_len, window) if window else cache_len
    caches = []
    for tag, count in layer_groups(cfg):
        kind, _ = tag
        if kind in ("attn", "shared_attn"):
            if cfg.attention == "mla" and kind == "attn":
                one = attn.init_mla_cache(cfg, batch, cache_len, dtype)
            else:
                one = attn.init_attn_cache(cfg, batch, alen, dtype)
        elif kind == "mamba2":
            one = rec.init_mamba2_state(cfg, batch)
            one = {"conv": one[0], "ssm": one[1]}
        elif kind == "rwkv6":
            s = rec.init_rwkv6_state(cfg, batch)
            one = {"tm_shift": s[0], "wkv": s[1], "cm_shift": s[2]}
        caches.append(jax.tree.map(lambda a: jnp.broadcast_to(a, (count,) + a.shape), one))

    cache = {"groups": caches}
    if cfg.encoder is not None:
        assert encoder_embeds is not None
        enc_out = _encode(cfg, params, encoder_embeds)
        # precompute cross K/V per decoder layer (stacked over the group)
        cross = []
        for gp in params["groups"]:
            ks, vs = jax.vmap(lambda lp: _cross_kv(lp["cross_attn"], cfg, enc_out))(gp)
            cross.append({"k": ks.astype(dtype), "v": vs.astype(dtype)})
        cache["cross"] = cross
    return cache


def _encode(cfg, params, encoder_embeds):
    params = _cast_params(cfg, params)
    e = encoder_embeds.astype(jnp.dtype(cfg.compute_dtype))
    e_pos = jnp.arange(e.shape[1], dtype=jnp.int32)
    for gp in params["encoder"]["groups"]:
        def ebody(carry, lp):
            h = apply_norm(lp["norm1"], carry, cfg.norm, cfg.norm_eps)
            a = _enc_self_attn(lp["attn"], cfg, h, e_pos)
            h2 = carry + a
            hh = apply_norm(lp["norm2"], h2, cfg.norm, cfg.norm_eps)
            return h2 + apply_mlp(lp["mlp"], hh, cfg.activation), None
        e, _ = jax.lax.scan(ebody, e, gp)
    return apply_norm(params["encoder"]["final_norm"], e, cfg.norm, cfg.norm_eps)


def _decode_layer(cfg, lp, shared, x, lcache, pos, tag, cross_kv=None,
                  moe_dispatch="einsum"):
    kind, is_moe = tag
    rs = _residual_scale(cfg)
    if kind == "mamba2":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, (cv, st) = rec.mamba2_forward(lp["mamba"], cfg, h,
                                         conv_state=lcache["conv"], ssm_state=lcache["ssm"])
        return x + y * rs, {"conv": cv, "ssm": st}
    if kind == "rwkv6":
        h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
        y, (sh, wkv) = rec.rwkv6_time_mix(lp["tm"], cfg, h,
                                          shift_state=lcache["tm_shift"], wkv_state=lcache["wkv"])
        x = x + y * rs
        h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
        y, cm_sh = rec.rwkv6_channel_mix(lp["tm"], h, shift_state=lcache["cm_shift"])
        return x + y * rs, {"tm_shift": sh, "wkv": wkv, "cm_shift": cm_sh}

    h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
    ap = shared if kind == "shared_attn" else lp["attn"]
    if cfg.attention == "mla" and kind == "attn":
        a, new_cache = attn.mla_decode(ap, cfg, h, lcache, pos)
    else:
        a, new_cache = attn.attention_decode(ap, cfg, h, lcache, pos,
                                             window=cfg.serve_window)
    if cfg.parallel_block:
        m = apply_mlp(lp["mlp"], h, cfg.activation)
        return x + (a + m) * rs, new_cache
    x = x + a * rs
    if cross_kv is not None:
        h = apply_norm(lp["cross_norm"], x, cfg.norm, cfg.norm_eps)
        c = _cross_decode(lp["cross_attn"], cfg, h, cross_kv)
        x = x + c * rs
    h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
    if is_moe:
        y, _ = moe_lib.moe_forward(lp["moe"], cfg, h, dispatch=moe_dispatch)
    else:
        y = apply_mlp(lp["mlp"], h, cfg.activation)
    return x + y * rs, new_cache


def _cross_decode(ap, cfg, x, cross_kv):
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ ap["wq"]).reshape(B, 1, H, hd)
    if "bq" in ap:
        q = q + ap["bq"].reshape(H, hd)
    k, v = cross_kv["k"], cross_kv["v"]
    scale = 1.0 / (hd ** 0.5)
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype)).astype(jnp.float32) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v)
    return attn._out_proj(ap, out.reshape(B, 1, H, hd))


def decode_step(cfg, params, cache, token, pos, *, moe_dispatch: str = "einsum"):
    """token (B, 1) int32; pos scalar int32. Returns (logits (B,1,V), cache)."""
    params = _cast_params(cfg, params)
    x = embed_tokens(params["embed"], token) * cfg.scale_emb
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    shared = params.get("shared_attn")
    new_groups = []
    for gi, (gp, (tag, count)) in enumerate(zip(params["groups"], layer_groups(cfg))):
        cross = cache.get("cross")
        gc = cache["groups"][gi]

        def body(carry, inp):
            h = carry
            lp, lc, ck = inp
            h2, nc = _decode_layer(cfg, lp, shared, h, lc, pos, tag, cross_kv=ck,
                                   moe_dispatch=moe_dispatch)
            return h2, nc

        if cross is None:
            x, new_gc = jax.lax.scan(lambda c, i: body(c, (i[0], i[1], None)), x, (gp, gc))
        else:
            x, new_gc = jax.lax.scan(lambda c, i: body(c, i), x, (gp, gc, cross[gi]))
        new_groups.append(new_gc)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    tied = params["embed"]["table"] if cfg.tie_embeddings else None
    logits = unembed(params.get("unembed"), x, tied_table=tied) * cfg.logits_scale
    logits = _mask_padded_vocab(cfg, logits)
    new_cache = dict(cache)
    new_cache["groups"] = new_groups
    return logits, new_cache
