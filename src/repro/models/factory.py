"""Parameter factory: builds param pytrees and, in abstract mode, the
parallel tree of logical sharding axes.

Every parameter in the model zoo is created through ``ParamFactory.param``
with a tuple of *logical axis names* (one per dim).  Sharding rules
(``repro/distributed/sharding.py``) map logical names -> mesh axes to
produce PartitionSpec trees with the exact same structure as the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AbstractParam:
    """Placeholder leaf used in abstract mode (records shape/axes/dtype)."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: str

    # make it usable as a pytree *leaf*
    def __hash__(self):
        return hash((self.shape, self.axes, self.dtype))


def is_abstract_leaf(x) -> bool:
    return isinstance(x, AbstractParam)


class ParamFactory:
    """Deterministic parameter creator.

    ``abstract=True`` builds an AbstractParam tree (no RNG, no memory) used
    for sharding-spec derivation and jax.eval_shape-style plumbing.
    """

    def __init__(self, key: Optional[jax.Array] = None, abstract: bool = False,
                 dtype=jnp.float32):
        self.key = key
        self.abstract = abstract
        self.dtype = dtype
        self._counter = 0

    def _next_key(self):
        self._counter += 1
        return jax.random.fold_in(self.key, self._counter)

    def param(self, shape, axes, init: str = "fan_in", scale: Optional[float] = None,
              dtype=None):
        shape = tuple(int(s) for s in shape)
        axes = tuple(axes)
        assert len(axes) == len(shape), f"axes {axes} vs shape {shape}"
        dtype = dtype or self.dtype
        if self.abstract:
            return AbstractParam(shape, axes, jnp.dtype(dtype).name)
        k = self._next_key()
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            std = scale if scale is not None else 0.02
            return (jax.random.normal(k, shape) * std).astype(dtype)
        if init == "fan_in":
            fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
            std = (scale if scale is not None else 1.0) / (fan_in ** 0.5)
            return (jax.random.normal(k, shape) * std).astype(dtype)
        if init == "uniform":
            lim = scale if scale is not None else 1.0 / (shape[0] ** 0.5)
            return jax.random.uniform(k, shape, minval=-lim, maxval=lim).astype(dtype)
        if init == "constant":
            return jnp.full(shape, scale, dtype)
        raise ValueError(f"unknown init {init}")


def abstract_to_shape_dtype(tree):
    """AbstractParam tree -> jax.ShapeDtypeStruct tree (for eval_shape etc.)."""
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, jnp.dtype(a.dtype)),
        tree, is_leaf=is_abstract_leaf)
