"""Architecture registry: maps --arch ids to config modules.

Each ``repro/configs/<id>.py`` exposes ``config()`` (full production spec,
cited) and ``smoke_config()`` (reduced family-preserving variant for CPU
tests).  The registry also records which input shapes each arch supports
(``long_500k`` needs sub-quadratic serving; whisper's enc-dec tops out at
its encoder frame budget — see DESIGN.md §5).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "llava_next_mistral_7b",
    "granite_moe_3b_a800m",
    "minicpm_2b",
    "starcoder2_3b",
    "command_r_35b",
    "minicpm3_4b",
    "zamba2_7b",
    "qwen3_moe_30b_a3b",
    "rwkv6_3b",
    "whisper_small",
)

# input-shape skips (DESIGN.md §5): whisper long_500k is architecturally
# meaningless (448-token decoder / 1500-frame encoder).
SKIPS = {
    ("whisper_small", "long_500k"): "enc-dec: decoder max positions 448; "
                                    "524288-token decode context is not defined for this arch",
}


def normalize(arch: str) -> str:
    return arch.replace("-", "_")


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.config()


def get_smoke_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.smoke_config()


def supported(arch: str, shape_name: str) -> bool:
    return (normalize(arch), shape_name) not in SKIPS


def skip_reason(arch: str, shape_name: str):
    return SKIPS.get((normalize(arch), shape_name))
