"""``FLServer`` — the federation as a live service (docs/SERVING.md).

The closed-loop runtimes pull completions from a simulated scheduler;
the server's hot loop instead drains a transport's upload queue into
windows and feeds each message through the SAME protocol objects
(``UploadPolicy`` / ``Aggregator``), codec plumbing and accounting the
runtimes use:

* scalar **reports** run the policy's ship/skip decision with exact
  fleet-wide state server-side (two-phase exchange: decision frames go
  back unbilled, exactly like the closed loop's in-process decision);
* accepted **updates** decode against the model the client actually
  downloaded (per-client base cache), enter a FedBuff-style buffer of
  ``buffer_size`` reconstructions and commit through the shared
  ``_flush_reconstructions`` math — ``buffer_size=1`` is the sequential
  per-arrival mix bit for bit;
* every event closes with a **download** carrying the latest global
  model; per-client version tracking feeds staleness weights s(tau).

``EventScheduler`` is reused for bookkeeping only (per-client byte
ledgers, and — under the single-threaded bridge driver — the exact
simulated clock); nothing here waits on simulated time.  Blocking
discipline: every transport receive carries a timeout (the
``serve-blocking-in-hotloop`` analysis rule enforces this), a stalled
fleet trips ``stall_timeout`` and the drain path commits whatever is
buffered instead of wedging.
"""
from __future__ import annotations

import time
from contextlib import nullcontext
from typing import Optional

import jax
import numpy as np

from repro.common.pytree import tree_bytes
from repro.core.metrics import CommStats, RoundRecord, RunResult
from repro.core.runtimes.common import (_attach_sim_result,
                                        _compressed_broadcast, _enc_seed,
                                        _finish_obs, _flush_reconstructions,
                                        _make_codecs, _obs_for_run,
                                        _scenario_models, _tree_apply_delta,
                                        _tree_delta, _BROADCAST)
from repro.core.scheduler import EventScheduler, SpeedModel
from repro.obs.console import progress
from repro.serve import messages as wire
from repro.serve.messages import BroadcastMsg, UploadMsg
from repro.serve.transport import Transport

# hot-loop poll granularity: long enough to sleep the loop when the
# fleet is quiet, short enough that stop()/stall checks stay responsive
_POLL = 0.05


class FLServer:
    """One federation behind a transport.  Lifecycle:

        server = FLServer(cfg, init_params_fn=..., evaluate_fn=...,
                          transport=transport)
        server.start()                      # init broadcasts
        result = server.run()               # hot loop until total_events
        # or: server.step(timeout) from an external loop (multi-tenant),
        #     then server.finalize()
    """

    def __init__(self, run_cfg, *, init_params_fn, evaluate_fn,
                 transport: Transport, total_events: Optional[int] = None,
                 sched: Optional[EventScheduler] = None,
                 speed: Optional[SpeedModel] = None,
                 account_bytes: bool = True, verbose: bool = False):
        alg, policy, aggregator = run_cfg.make_algorithm()
        if alg.event_mode != "async":
            raise ValueError(
                f"algorithm {run_cfg.algorithm!r} runs a sync barrier "
                "(event_mode='sync-barrier') — the live serve loop has no "
                "barrier; use an async algorithm (afl/vafl/eaflm/fedasync)")
        self.cfg = run_cfg
        self.policy, self.aggregator = policy, aggregator
        N = run_cfg.num_clients
        policy.begin_run(N)
        aggregator.begin_run(N)
        # the same init-key derivation as the closed-loop runtimes, so a
        # serve run and a simulated run start from the same parameters
        _, krng = jax.random.split(jax.random.key(run_cfg.seed))
        self.global_params = init_params_fn(krng)
        self.evaluate_fn = evaluate_fn
        self.comm = CommStats(model_bytes=tree_bytes(self.global_params))
        self.codec, self.bcodec, _ef = _make_codecs(run_cfg)  # ef is client-side
        self.obs = _obs_for_run(run_cfg)
        self.transport = transport
        self.verbose = verbose

        # scheduler: bookkeeping ledgers (and, when an external driver
        # owns it, the exact simulated clock the result reports) — built
        # exactly like the closed loop's, scenario models included, so
        # the bridge driver's sched arithmetic matches events.py
        if sched is None:
            compute, net, avail = _scenario_models(run_cfg, N)
            speed = speed or compute or SpeedModel.paper_testbed(
                N, run_cfg.seed)
            sched = EventScheduler(N, speed, network=net,
                                   availability=avail, obs=self.obs)
        self.sched = sched
        self._account_bytes = account_bytes

        # the two-phase exchange exists iff the policy can decline: it
        # reports scalars or overrides the default always-ship decide()
        from repro.algorithms.base import UploadPolicy as _Base
        self.two_phase = bool(policy.reports
                              or type(policy).decide is not _Base.decide)

        self.model_version = np.zeros(N, int)
        self.server_version = 0
        self.prev_global = self.global_params
        self.prev_prev_global = self.global_params
        # the model each client last downloaded — the codec delta's
        # decode base (lossy under a broadcast codec, exactly what the
        # client trains from)
        self.client_base = [self.global_params] * N
        self._buffer: list = []          # reconstruction trees
        self._buf_stale: list = []       # their staleness weights s(tau)
        self._buf_recv: list = []        # their transport arrival stamps
        self.K = max(1, run_cfg.buffer_size)
        self.window = run_cfg.max_batch if run_cfg.max_batch > 0 else N
        self.records: list = []
        self.processed = 0               # completed events (downloads sent)
        self.total_events = (run_cfg.rounds * N if total_events is None
                             else total_events)
        self._pending: dict = {}         # client -> sim_time of an accepted
        #                                  report whose update hasn't landed
        self._last_seq = np.full(N, -1, np.int64)   # per-client FIFO check
        self._stopping = False
        self._finalized = None

    # ----------------------------------------------------------- lifecycle ---

    def start(self) -> None:
        """Send every client its init broadcast: the initial model plus
        the run flags it needs.  Bootstrap traffic — not billed in
        CommStats (the closed loop's clients start from the same init
        implicitly)."""
        meta = {"schema": wire.WIRE_SCHEMA,
                "needs_values": self.policy.needs_values,
                "needs_norms": self.policy.needs_norms,
                "two_phase": self.two_phase,
                "compressor": self.cfg.compressor,
                "error_feedback": self.cfg.error_feedback,
                "seed": self.cfg.seed,
                "rounds": self.cfg.rounds}
        for i in range(self.cfg.num_clients):
            self.transport.send_broadcast(i, BroadcastMsg(
                kind=wire.INIT, version=0, tree=self.global_params,
                meta=meta))

    def stop(self) -> None:
        """Ask the hot loop to drain and return after the current window."""
        self._stopping = True

    def run(self, stall_timeout: float = 60.0) -> RunResult:
        """The hot loop: drain upload windows until ``total_events``
        events completed, ``stop()`` was called, or no message arrived
        for ``stall_timeout`` seconds (dead fleet — drain and return
        rather than wedge)."""
        last_msg = time.monotonic()
        while self.processed < self.total_events and not self._stopping:
            if self.step(timeout=_POLL):
                last_msg = time.monotonic()
            elif time.monotonic() - last_msg > stall_timeout:
                break
        return self.finalize()

    def step(self, timeout: float = 0.0) -> int:
        """Drain and process ONE window (up to ``max_batch`` messages
        already queued, waiting at most ``timeout`` for the first).
        Returns the number of messages processed — 0 when the queue was
        quiet, so external loops (multi-tenant) can round-robin without
        blocking."""
        window = self.transport.drain_uploads(self.window, timeout=timeout)
        if not window:
            return 0
        if self.obs is not None:
            self.obs.queue_depth(self.transport.queue_depth() + len(window))
            h0 = self.obs.host_now()
        for msg in window:
            self._handle(msg)
        if self.obs is not None:
            self.obs.window(len(window), window[0].sim_time,
                            window[-1].sim_time, h0)
        return len(window)

    # ------------------------------------------------------ event handling ---

    def _handle(self, msg: UploadMsg) -> None:
        i = int(msg.client)
        if msg.seq <= self._last_seq[i]:
            raise RuntimeError(
                f"transport reordered client {i}: seq {msg.seq} after "
                f"{self._last_seq[i]} — per-client FIFO is a transport "
                "contract")
        self._last_seq[i] = msg.seq
        if msg.kind == wire.REPORT:
            self._handle_report(i, msg)
        elif msg.kind == wire.UPDATE:
            self._handle_update(i, msg)
        else:
            raise ValueError(f"unknown upload kind {msg.kind!r}")

    def _handle_report(self, i: int, msg: UploadMsg) -> None:
        """Phase 1 of a two-phase event: the scalar report and the
        server-side ship/skip decision (exact policy state — VAFL's gate
        reads the whole fleet's reported values)."""
        t = msg.sim_time
        u0 = self.comm.uplink_bytes
        thr = self.policy.window_threshold(self._server_delta)
        if self.policy.reports:
            self.comm.record_report(1)
            if self.obs is not None:
                self.obs.report(i, t)
        upload = self.policy.decide(i, msg.value, msg.norm, thr)
        if upload:
            # decision frames are control-plane traffic (unbilled); the
            # payload arrives as this client's next message.  The report's
            # wire bytes carry over so the whole exchange lands in one
            # ledger entry (deltas are within-message only — between a
            # report and its update, OTHER clients move the counters)
            self._pending[i] = (t, self.comm.uplink_bytes - u0)
            self.transport.send_broadcast(
                i, BroadcastMsg(kind=wire.DECISION, upload=True,
                                version=self.server_version))
        else:
            self._finish_event(i, t, self.comm.uplink_bytes - u0)

    def _handle_update(self, i: int, msg: UploadMsg) -> None:
        """An accepted upload's payload: decode, buffer, commit every K."""
        t = msg.sim_time
        pend = self._pending.pop(i, None)
        carry = pend[1] if pend is not None else 0   # the report's bytes
        u0 = self.comm.uplink_bytes
        p0 = self.comm.upload_payload_bytes
        if self.codec.is_identity:
            recon = msg.payload            # the full parameter tree
            self.comm.record_upload(1)
        else:
            with (self.obs.timed("decode", client=i, codec=self.codec.name)
                  if self.obs is not None else nullcontext()):
                decoded = self.codec.decode(msg.payload)
            recon = _tree_apply_delta(self.client_base[i], decoded)
            self.comm.record_upload(1, nbytes=msg.payload.nbytes)
        staleness = self.server_version - self.model_version[i]
        if self.obs is not None:
            self.obs.upload(i, t, staleness=int(staleness),
                            nbytes=self.comm.upload_payload_bytes - p0,
                            codec=self.codec.name)
        self._buffer.append(recon)
        self._buf_stale.append(self.aggregator.stale_weight(int(staleness)))
        self._buf_recv.append(msg.recv_host)
        if len(self._buffer) >= self.K:
            self._flush(t)
        self._finish_event(i, t, carry + self.comm.uplink_bytes - u0)

    def _flush(self, sim_time: float) -> None:
        """Commit the buffer: one staleness-weighted FedBuff mix through
        the shared runtime math, then advance the server version."""
        if self.obs is not None:
            self.obs.flush(len(self._buffer), sim_time)
        self.prev_prev_global = self.prev_global
        self.prev_global = self.global_params
        self.global_params = _flush_reconstructions(
            self.aggregator, self.global_params, self._buffer,
            self._buf_stale)
        self.server_version += 1
        if self.obs is not None:
            now = time.monotonic()
            for stamp in self._buf_recv:
                if stamp:
                    self.obs.commit_latency(now - stamp)
        self._buffer.clear()
        self._buf_stale.clear()
        self._buf_recv.clear()

    def _finish_event(self, i: int, t: float, up_bytes: int) -> None:
        """Every event's tail: the download broadcast, version tracking,
        byte ledgers, and the eval-boundary record."""
        d0 = self.comm.downlink_bytes
        if self.bcodec is None:
            sent = self.global_params
            self.comm.record_broadcast(1)
        else:
            sent = _compressed_broadcast(
                self.bcodec, self.comm, self.global_params, 1,
                _enc_seed(self.cfg, self.processed, i, _BROADCAST),
                obs=self.obs)
        if self.obs is not None:
            self.obs.broadcast(i, t, nbytes=self.comm.downlink_bytes - d0,
                               codec=None if self.bcodec is None
                               else self.bcodec.name)
        self.client_base[i] = sent
        self.model_version[i] = self.server_version
        self.transport.send_broadcast(i, BroadcastMsg(
            kind=wire.DOWNLOAD, version=self.server_version, tree=sent))
        if self._account_bytes:
            self.sched.account_bytes(i, up_bytes,
                                     self.comm.downlink_bytes - d0)
        self.processed += 1
        if self.processed % self.cfg.events_per_eval == 0:
            h0 = self.obs.host_now() if self.obs is not None else 0.0
            acc = float(self.evaluate_fn(self.global_params))
            if self.obs is not None:
                self.obs.eval_event(self.processed, t, h0)
            self.records.append(RoundRecord(
                round=self.processed, time=t, global_acc=acc,
                uploads_so_far=self.comm.model_uploads))
            if self.verbose:
                progress(f"[{self.cfg.algorithm}/serve] ev "
                         f"{self.processed:4d} t={t:8.1f} acc={acc:.4f} "
                         f"uploads={self.comm.model_uploads}")

    def _server_delta(self):
        return _tree_delta(self.prev_global, self.prev_prev_global)

    # ------------------------------------------------------------ shutdown ---

    def finalize(self, drain_timeout: float = 1.0) -> RunResult:
        """Graceful drain + shutdown: process everything still queued,
        commit any partial buffer (no accepted update is ever lost),
        discard wedged two-phase exchanges through the failure hook,
        send final broadcasts, seal obs, build the ``RunResult``.
        Idempotent — the first call's result is returned thereafter."""
        if self._finalized is not None:
            return self._finalized
        deadline = time.monotonic() + drain_timeout
        while self.processed < self.total_events:
            n = self.step(timeout=0.01)
            if n == 0 and time.monotonic() > deadline:
                break
        for i, (t, _carry) in sorted(self._pending.items()):
            # a client accepted for upload never delivered its payload
            # (killed worker): discard, count the failure, move on
            if self.obs is not None:
                self.obs.failure(i, t)
        self._pending.clear()
        if self._buffer:
            self._flush(float(self.sched.now))
        for i in range(self.cfg.num_clients):
            self.transport.send_broadcast(
                i, BroadcastMsg(kind=wire.FINAL,
                                version=self.server_version))
        res = RunResult(self.cfg.algorithm, self.records, self.comm,
                        self.cfg.target_acc).finalize_target()
        res = _finish_obs(_attach_sim_result(res, self.sched), self.obs)
        self._finalized = res
        return res
