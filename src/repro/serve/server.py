"""``FLServer`` — the federation as a live service (docs/SERVING.md).

The closed-loop runtimes pull completions from a simulated scheduler;
the server's hot loop instead drains a transport's upload queue into
windows and feeds each message through the SAME protocol objects
(``UploadPolicy`` / ``Aggregator``), codec plumbing and accounting the
runtimes use:

* scalar **reports** run the policy's ship/skip decision with exact
  fleet-wide state server-side (two-phase exchange: decision frames go
  back unbilled, exactly like the closed loop's in-process decision);
* accepted **updates** decode against the model the client actually
  downloaded (per-client base cache), enter a FedBuff-style buffer of
  ``buffer_size`` reconstructions and commit through the shared
  ``_flush_reconstructions`` math — ``buffer_size=1`` is the sequential
  per-arrival mix bit for bit;
* every event closes with a **download** carrying the latest global
  model; per-client version tracking feeds staleness weights s(tau).

``EventScheduler`` is reused for bookkeeping only (per-client byte
ledgers, and — under the single-threaded bridge driver — the exact
simulated clock); nothing here waits on simulated time.  Blocking
discipline: every transport receive carries a timeout (the
``serve-blocking-in-hotloop`` analysis rule enforces this), a stalled
fleet trips ``stall_timeout`` and the drain path commits whatever is
buffered instead of wedging.

Resilience (docs/RESILIENCE.md): uploads are deduplicated by
``(client, seq)`` — a replayed seq (client retry, chaos duplicate)
re-sends the cached reply instead of reprocessing, so at-least-once
clients compose into exactly-once processing; accepted two-phase
reports carry a per-exchange deadline (``exchange_timeout``) so a
wedged exchange is discarded without waiting for the global stall;
clients silent past ``liveness_timeout`` (or reported dead by the
transport) are evicted, and re-admitted on their next message — with a
fresh decode base when they restarted (seq regressed to 0) or
reconnected.  ``checkpoint_path``/``checkpoint_every`` on the config
write one atomic full-run checkpoint (``repro.checkpoint``), and
``resume=True`` continues from it.
"""
from __future__ import annotations

import os
import time
from contextlib import nullcontext
from typing import Optional

import jax
import numpy as np

from repro.common.pytree import tree_bytes
from repro.core.metrics import CommStats, RoundRecord, RunResult
from repro.core.runtimes.common import (_attach_sim_result,
                                        _compressed_broadcast, _enc_seed,
                                        _finish_obs, _flush_reconstructions,
                                        _make_codecs, _obs_for_run,
                                        _scenario_models, _tree_apply_delta,
                                        _tree_delta, _BROADCAST)
from repro.core.scheduler import EventScheduler, SpeedModel
from repro.obs.console import progress
from repro.serve import messages as wire
from repro.serve.messages import BroadcastMsg, UploadMsg
from repro.serve.transport import Transport

# hot-loop poll granularity: long enough to sleep the loop when the
# fleet is quiet, short enough that stop()/stall checks stay responsive
_POLL = 0.05


class FLServer:
    """One federation behind a transport.  Lifecycle:

        server = FLServer(cfg, init_params_fn=..., evaluate_fn=...,
                          transport=transport)
        server.start()                      # init broadcasts
        result = server.run()               # hot loop until total_events
        # or: server.step(timeout) from an external loop (multi-tenant),
        #     then server.finalize()
    """

    def __init__(self, run_cfg, *, init_params_fn, evaluate_fn,
                 transport: Transport, total_events: Optional[int] = None,
                 sched: Optional[EventScheduler] = None,
                 speed: Optional[SpeedModel] = None,
                 account_bytes: bool = True, verbose: bool = False,
                 exchange_timeout: Optional[float] = None,
                 liveness_timeout: Optional[float] = None,
                 resume_fresh_clients: bool = True,
                 name: str = "default"):
        alg, policy, aggregator = run_cfg.make_algorithm()
        if alg.event_mode != "async":
            raise ValueError(
                f"algorithm {run_cfg.algorithm!r} runs a sync barrier "
                "(event_mode='sync-barrier') — the live serve loop has no "
                "barrier; use an async algorithm (afl/vafl/eaflm/fedasync)")
        self.cfg = run_cfg
        # the tenant label the live telemetry plane (repro.obs.live)
        # tags this federation's metrics/scoreboard with
        self.name = name
        self.policy, self.aggregator = policy, aggregator
        N = run_cfg.num_clients
        policy.begin_run(N)
        aggregator.begin_run(N)
        # the same init-key derivation as the closed-loop runtimes, so a
        # serve run and a simulated run start from the same parameters
        _, krng = jax.random.split(jax.random.key(run_cfg.seed))
        self.global_params = init_params_fn(krng)
        self.evaluate_fn = evaluate_fn
        self.comm = CommStats(model_bytes=tree_bytes(self.global_params))
        self.codec, self.bcodec, _ef = _make_codecs(run_cfg)  # ef is client-side
        self.obs = _obs_for_run(run_cfg)
        self.transport = transport
        self.verbose = verbose

        # scheduler: bookkeeping ledgers (and, when an external driver
        # owns it, the exact simulated clock the result reports) — built
        # exactly like the closed loop's, scenario models included, so
        # the bridge driver's sched arithmetic matches events.py
        if sched is None:
            compute, net, avail = _scenario_models(run_cfg, N)
            speed = speed or compute or SpeedModel.paper_testbed(
                N, run_cfg.seed)
            sched = EventScheduler(N, speed, network=net,
                                   availability=avail, obs=self.obs)
        self.sched = sched
        self._account_bytes = account_bytes

        # the two-phase exchange exists iff the policy can decline: it
        # reports scalars or overrides the default always-ship decide()
        from repro.algorithms.base import UploadPolicy as _Base
        self.two_phase = bool(policy.reports
                              or type(policy).decide is not _Base.decide)

        self.model_version = np.zeros(N, int)
        self.server_version = 0
        self.prev_global = self.global_params
        self.prev_prev_global = self.global_params
        # the model each client last downloaded — the codec delta's
        # decode base (lossy under a broadcast codec, exactly what the
        # client trains from)
        self.client_base = [self.global_params] * N
        self._buffer: list = []          # reconstruction trees
        self._buf_stale: list = []       # their staleness weights s(tau)
        self._buf_recv: list = []        # their transport arrival stamps
        self.K = max(1, run_cfg.buffer_size)
        self.window = run_cfg.max_batch if run_cfg.max_batch > 0 else N
        self.records: list = []
        self.processed = 0               # completed events (downloads sent)
        self.total_events = (run_cfg.rounds * N if total_events is None
                             else total_events)
        self._pending: dict = {}         # client -> (sim_time, carried
        #                                  bytes, host deadline) of an
        #                                  accepted report whose update
        #                                  hasn't landed
        self._last_seq = np.full(N, -1, np.int64)   # dedup watermark
        self._stopping = False
        self._finalized = None

        # resilience state (docs/RESILIENCE.md): reply cache for dedup
        # replay, liveness bookkeeping, and the counters the chaos soak
        # reconciles against client-side retry counts
        self.exchange_timeout = exchange_timeout
        self.liveness_timeout = liveness_timeout
        self._last_reply: dict = {}       # client -> last reply sent
        self._evicted: set = set()
        self.dead_reason: dict = {}       # client -> why it was evicted
        self._last_heard = np.full(N, time.monotonic())
        self.accepted_by_client = np.zeros(N, np.int64)  # committed updates
        self.duplicates = 0
        self.evictions = 0
        self.readmissions = 0
        self.exchange_expired = 0
        self.wire_errors = 0
        self.restarts = 0

        # full-run checkpointing: cfg-driven, one atomic file; resume
        # restores it when present.  resume_fresh_clients=True (the live
        # fleet restart) rebases every client on the restored global;
        # the bridge driver passes False and reconstructs client state
        # from the checkpoint instead (bit-equal continuation).
        self._ckpt_path = run_cfg.checkpoint_path
        self._ckpt_every = run_cfg.checkpoint_every
        if (run_cfg.resume and self._ckpt_path
                and os.path.exists(self._ckpt_path)):
            self.restore_checkpoint(self._ckpt_path,
                                    fresh_clients=resume_fresh_clients)

    # ----------------------------------------------------------- lifecycle ---

    def _init_meta(self) -> dict:
        return {"schema": wire.WIRE_SCHEMA,
                "needs_values": self.policy.needs_values,
                "needs_norms": self.policy.needs_norms,
                "two_phase": self.two_phase,
                "compressor": self.cfg.compressor,
                "error_feedback": self.cfg.error_feedback,
                "seed": self.cfg.seed,
                "rounds": self.cfg.rounds}

    def start(self) -> None:
        """Send every client its init broadcast: the initial model plus
        the run flags it needs.  Bootstrap traffic — not billed in
        CommStats (the closed loop's clients start from the same init
        implicitly).  After a resume this broadcasts the RESTORED
        global, so a restarted fleet bootstraps from where the run left
        off."""
        meta = self._init_meta()
        for i in range(self.cfg.num_clients):
            self.transport.send_broadcast(i, BroadcastMsg(
                kind=wire.INIT, version=self.server_version,
                tree=self.global_params, meta=meta))

    def stop(self) -> None:
        """Ask the hot loop to drain and return after the current window."""
        self._stopping = True

    def run(self, stall_timeout: float = 60.0) -> RunResult:
        """The hot loop: drain upload windows until ``total_events``
        events completed, ``stop()`` was called, or no message arrived
        for ``stall_timeout`` seconds (dead fleet — drain and return
        rather than wedge)."""
        if self.obs is not None:       # opt-in live metric sampler
            self.obs.sampler_start()
        last_msg = time.monotonic()
        while self.processed < self.total_events and not self._stopping:
            if self.step(timeout=_POLL):
                last_msg = time.monotonic()
            elif time.monotonic() - last_msg > stall_timeout:
                break
        return self.finalize()

    def step(self, timeout: float = 0.0) -> int:
        """Drain and process ONE window (up to ``max_batch`` messages
        already queued, waiting at most ``timeout`` for the first).
        Returns the number of messages processed — 0 when the queue was
        quiet, so external loops (multi-tenant) can round-robin without
        blocking."""
        self._police()
        window = self.transport.drain_uploads(self.window, timeout=timeout)
        if not window:
            return 0
        if self.obs is not None:
            self.obs.queue_depth(self.transport.queue_depth() + len(window))
            h0 = self.obs.host_now()
        for msg in window:
            self._handle(msg)
        if self.obs is not None:
            self.obs.window(len(window), window[0].sim_time,
                            window[-1].sim_time, h0)
        return len(window)

    # --------------------------------------------------------- liveness ---

    def _police(self, now: Optional[float] = None) -> None:
        """Per-step housekeeping: expire wedged two-phase exchanges,
        consume the transport's dead/reconnect surfaces, and evict
        clients silent past the liveness deadline.  Every path is
        idempotent — flapping clients cycle evict/readmit cleanly."""
        now = time.monotonic() if now is None else now
        if self.exchange_timeout is not None and self._pending:
            for i in [i for i, (_, _, dl) in self._pending.items()
                      if dl is not None and now >= dl]:
                t, _, _ = self._pending.pop(i)
                self.exchange_expired += 1
                if self.obs is not None:
                    self.obs.failure(i, t, kind="exchange-timeout")
        tr = self.transport
        if hasattr(tr, "poll_fault_stats") and self.obs is not None:
            # chaos ground truth -> first-class metrics (repro.obs.live):
            # the soak reconciles these counters against transport.stats
            for kind, n in tr.poll_fault_stats().items():
                self.obs.fault(kind, n)
        if hasattr(tr, "poll_wire_errors"):
            n = tr.poll_wire_errors()
            if n:
                self.wire_errors += n
                if self.obs is not None:
                    self.obs.wire_error(n)
        if hasattr(tr, "dead_clients"):
            reasons = (tr.dead_reasons()
                       if hasattr(tr, "dead_reasons") else {})
            for i in tr.dead_clients():
                if i not in self._evicted:
                    reason = reasons.get(i, "transport-dead")
                    if reason == "wire-error":
                        self.wire_errors += 1
                        if self.obs is not None:
                            self.obs.wire_error()
                    self._evict(i, reason=reason)
        if hasattr(tr, "poll_reconnects"):
            for i in tr.poll_reconnects():
                self._readmit(i, fresh=True)
        if self.liveness_timeout is not None:
            for i in np.nonzero(
                    now - self._last_heard > self.liveness_timeout)[0]:
                i = int(i)
                if i not in self._evicted:
                    self._evict(i, reason="liveness")

    def _evict(self, i: int, *, reason: str) -> None:
        """Mark a client dead: discard its wedged exchange (the failure
        path) and stop expecting traffic until it re-admits."""
        self._evicted.add(i)
        self.dead_reason[i] = reason
        self.evictions += 1
        pend = self._pending.pop(i, None)
        if self.obs is not None:
            self.obs.evict(i, self.sched.now, reason=reason)
            if pend is not None:
                self.obs.failure(i, pend[0], kind="evicted")

    def _readmit(self, i: int, *, fresh: bool) -> None:
        """Welcome an evicted client back.  ``fresh`` (a restarted or
        reconnected client) rebases it on the current global model:
        fresh decode base, current version, seq watermark reset, reply
        cache dropped, and a new init broadcast so the fresh process
        can bootstrap."""
        self._evicted.discard(i)
        self.dead_reason.pop(i, None)
        self.readmissions += 1
        self._last_heard[i] = time.monotonic()
        if fresh:
            self.client_base[i] = self.global_params
            self.model_version[i] = self.server_version
            self._last_seq[i] = -1
            self._last_reply.pop(i, None)
            self._pending.pop(i, None)
            self.transport.send_broadcast(i, BroadcastMsg(
                kind=wire.INIT, version=self.server_version,
                tree=self.global_params, meta=self._init_meta()))
        if self.obs is not None:
            self.obs.readmit(i, self.sched.now, fresh=fresh)

    # ------------------------------------------------------ event handling ---

    def _handle(self, msg: UploadMsg) -> None:
        i = int(msg.client)
        self._last_heard[i] = time.monotonic()
        if msg.seq <= self._last_seq[i]:
            if i in self._evicted and msg.seq == 0:
                # a restarted client (fresh process, seq reset) rather
                # than a duplicate: rebase it and process the message
                self.restarts += 1
                self._readmit(i, fresh=True)
            else:
                # a client retry or a chaos duplicate: idempotent dedup —
                # count it and replay the cached reply so a client whose
                # reply was lost makes progress without reprocessing
                self.duplicates += 1
                if i in self._evicted:
                    self._readmit(i, fresh=False)
                if self.obs is not None:
                    self.obs.duplicate(i, msg.sim_time)
                last = self._last_reply.get(i)
                if last is not None:
                    self.transport.send_broadcast(i, last)
                return
        elif i in self._evicted:
            self._readmit(i, fresh=False)
        self._last_seq[i] = msg.seq
        if msg.kind == wire.REPORT:
            self._handle_report(i, msg)
        elif msg.kind == wire.UPDATE:
            self._handle_update(i, msg)
        else:
            raise ValueError(f"unknown upload kind {msg.kind!r}")

    def _handle_report(self, i: int, msg: UploadMsg) -> None:
        """Phase 1 of a two-phase event: the scalar report and the
        server-side ship/skip decision (exact policy state — VAFL's gate
        reads the whole fleet's reported values)."""
        t = msg.sim_time
        u0 = self.comm.uplink_bytes
        thr = self.policy.window_threshold(self._server_delta)
        if self.policy.reports:
            self.comm.record_report(1)
            if self.obs is not None:
                self.obs.report(i, t)
        upload = self.policy.decide(i, msg.value, msg.norm, thr)
        if upload:
            # decision frames are control-plane traffic (unbilled); the
            # payload arrives as this client's next message.  The report's
            # wire bytes carry over so the whole exchange lands in one
            # ledger entry (deltas are within-message only — between a
            # report and its update, OTHER clients move the counters).
            # The exchange gets its own host deadline (exchange_timeout)
            # so a wedged client doesn't hold a pending slot forever.
            deadline = (None if self.exchange_timeout is None
                        else time.monotonic() + self.exchange_timeout)
            self._pending[i] = (t, self.comm.uplink_bytes - u0, deadline)
            reply = BroadcastMsg(kind=wire.DECISION, upload=True,
                                 version=self.server_version,
                                 ack_seq=msg.seq)
            self._last_reply[i] = reply
            self.transport.send_broadcast(i, reply)
        else:
            self._finish_event(i, t, self.comm.uplink_bytes - u0,
                               ack_seq=msg.seq)

    def _handle_update(self, i: int, msg: UploadMsg) -> None:
        """An accepted upload's payload: decode, buffer, commit every K."""
        t = msg.sim_time
        pend = self._pending.pop(i, None)
        carry = pend[1] if pend is not None else 0   # the report's bytes
        u0 = self.comm.uplink_bytes
        p0 = self.comm.upload_payload_bytes
        if self.codec.is_identity:
            recon = msg.payload            # the full parameter tree
            self.comm.record_upload(1)
        else:
            with (self.obs.timed("decode", client=i, codec=self.codec.name)
                  if self.obs is not None else nullcontext()):
                decoded = self.codec.decode(msg.payload)
            recon = _tree_apply_delta(self.client_base[i], decoded)
            self.comm.record_upload(1, nbytes=msg.payload.nbytes)
        staleness = self.server_version - self.model_version[i]
        if self.obs is not None:
            self.obs.upload(i, t, staleness=int(staleness),
                            nbytes=self.comm.upload_payload_bytes - p0,
                            codec=self.codec.name)
        self._buffer.append(recon)
        self._buf_stale.append(self.aggregator.stale_weight(int(staleness)))
        self._buf_recv.append(msg.recv_host)
        self.accepted_by_client[i] += 1
        if len(self._buffer) >= self.K:
            self._flush(t)
        self._finish_event(i, t, carry + self.comm.uplink_bytes - u0,
                           ack_seq=msg.seq)

    def _flush(self, sim_time: float) -> None:
        """Commit the buffer: one staleness-weighted FedBuff mix through
        the shared runtime math, then advance the server version."""
        if self.obs is not None:
            self.obs.flush(len(self._buffer), sim_time)
        self.prev_prev_global = self.prev_global
        self.prev_global = self.global_params
        self.global_params = _flush_reconstructions(
            self.aggregator, self.global_params, self._buffer,
            self._buf_stale)
        self.server_version += 1
        if self.obs is not None:
            now = time.monotonic()
            for stamp in self._buf_recv:
                if stamp:
                    self.obs.commit_latency(now - stamp)
        self._buffer.clear()
        self._buf_stale.clear()
        self._buf_recv.clear()

    def _finish_event(self, i: int, t: float, up_bytes: int,
                      ack_seq: int = -1) -> None:
        """Every event's tail: the download broadcast, version tracking,
        byte ledgers, and the eval-boundary record.  ``ack_seq`` echoes
        the upload seq this download answers (reply matching on a
        retrying client)."""
        d0 = self.comm.downlink_bytes
        if self.bcodec is None:
            sent = self.global_params
            self.comm.record_broadcast(1)
        else:
            sent = _compressed_broadcast(
                self.bcodec, self.comm, self.global_params, 1,
                _enc_seed(self.cfg, self.processed, i, _BROADCAST),
                obs=self.obs)
        if self.obs is not None:
            self.obs.broadcast(i, t, nbytes=self.comm.downlink_bytes - d0,
                               codec=None if self.bcodec is None
                               else self.bcodec.name)
        self.client_base[i] = sent
        self.model_version[i] = self.server_version
        reply = BroadcastMsg(kind=wire.DOWNLOAD,
                             version=self.server_version, tree=sent,
                             ack_seq=ack_seq)
        self._last_reply[i] = reply
        self.transport.send_broadcast(i, reply)
        if self._account_bytes:
            self.sched.account_bytes(i, up_bytes,
                                     self.comm.downlink_bytes - d0)
        self.processed += 1
        if self.processed % self.cfg.events_per_eval == 0:
            h0 = self.obs.host_now() if self.obs is not None else 0.0
            acc = float(self.evaluate_fn(self.global_params))
            if self.obs is not None:
                self.obs.eval_event(self.processed, t, h0)
            self.records.append(RoundRecord(
                round=self.processed, time=t, global_acc=acc,
                uploads_so_far=self.comm.model_uploads))
            if self.verbose:
                progress(f"[{self.cfg.algorithm}/serve] ev "
                         f"{self.processed:4d} t={t:8.1f} acc={acc:.4f} "
                         f"uploads={self.comm.model_uploads}")
        # checkpoint AFTER the eval-boundary record: an event that both
        # records and checkpoints must bundle its record, or a resume
        # from this file would silently skip it
        if self._ckpt_every and self.processed % self._ckpt_every == 0:
            self.save_checkpoint()

    def _server_delta(self):
        return _tree_delta(self.prev_global, self.prev_prev_global)

    # ------------------------------------------------------ live plane ---

    def scoreboard(self) -> dict:
        """The per-client health scoreboard (repro.obs.live): byte
        ledgers, staleness, liveness — the /clients payload."""
        from repro.obs.live import client_scoreboard
        return client_scoreboard(self)

    def absorb_client_stats(self, workers) -> None:
        """Fold the fleet's client-side stats (retry counts) into the
        obs metrics after the workers joined.  Idempotent — the counter
        is SET to the fleet total, not incremented — and it refreshes an
        already-sealed result's snapshot, because client threads only
        stop after ``finalize()`` returned."""
        if self.obs is None:
            return
        total = sum(getattr(w, "stats", {}).get("retries", 0)
                    for w in workers)
        self.obs.metrics.counter("client_retries").value = int(total)
        tr = self.transport    # faults injected between finalize and the
        if hasattr(tr, "poll_fault_stats"):     # last join land here too
            for kind, n in tr.poll_fault_stats().items():
                self.obs.fault(kind, n)
        if (self._finalized is not None
                and self._finalized.metrics is not None):
            self._finalized.metrics = self.obs.metrics.snapshot()

    # ---------------------------------------------------- checkpointing ---

    def save_checkpoint(self, path: Optional[str] = None) -> str:
        """Write one atomic full-run checkpoint: everything the serve
        loop needs to continue — model lineage, per-client bases and
        versions, dedup watermarks, the FedBuff buffer, CommStats,
        records, policy state, the scheduler snapshot, resilience
        counters and obs metrics."""
        from repro import checkpoint as ck
        path = path or self._ckpt_path
        if not path:
            raise ValueError("no checkpoint_path configured")
        h0 = self.obs.host_now() if self.obs is not None else 0.0
        state = {
            "processed": self.processed,
            "server_version": self.server_version,
            "model_version": self.model_version.copy(),
            "last_seq": self._last_seq.copy(),
            "global_params": ck.tree_to_host(self.global_params),
            "prev_global": ck.tree_to_host(self.prev_global),
            "prev_prev_global": ck.tree_to_host(self.prev_prev_global),
            "client_base": [ck.tree_to_host(t) for t in self.client_base],
            "buffer": [ck.tree_to_host(t) for t in self._buffer],
            "buf_stale": list(self._buf_stale),
            "comm": dict(self.comm.__dict__),
            "records": list(self.records),
            "policy": self.policy.state(),
            "sched": self.sched.snapshot(),
            "accepted_by_client": self.accepted_by_client.copy(),
            "counters": {"duplicates": self.duplicates,
                         "evictions": self.evictions,
                         "readmissions": self.readmissions,
                         "exchange_expired": self.exchange_expired,
                         "wire_errors": self.wire_errors,
                         "restarts": self.restarts},
            "obs": (self.obs.metrics.snapshot()
                    if self.obs is not None else None),
        }
        fp = ck.run_fingerprint(self.cfg, "serve", self.global_params)
        ck.save_run_state(path, state, fp)
        if self.obs is not None:
            self.obs.checkpoint(self.processed, h0)
        return path

    def restore_checkpoint(self, path: Optional[str] = None, *,
                           fresh_clients: bool = True) -> None:
        """Restore a ``save_checkpoint`` bundle (fingerprint-validated —
        a mismatched config or model shape raises
        ``CheckpointMismatchError``).  ``fresh_clients=True`` is the
        live fleet restart: every client is rebased on the restored
        global (fresh decode base, current version, seq watermarks
        reset) and ``start()`` re-bootstraps them.  ``False`` keeps the
        exact per-client state for a driver that reconstructs its
        clients from the checkpoint (the bit-equal resume path)."""
        from repro import checkpoint as ck
        path = path or self._ckpt_path
        fp = ck.run_fingerprint(self.cfg, "serve", self.global_params)
        st = ck.load_run_state(path, fp)
        h0 = self.obs.host_now() if self.obs is not None else 0.0
        self.processed = int(st["processed"])
        self.server_version = int(st["server_version"])
        self.model_version = np.asarray(st["model_version"], int).copy()
        self._last_seq = np.asarray(st["last_seq"], np.int64).copy()
        self.global_params = ck.tree_to_device(st["global_params"])
        self.prev_global = ck.tree_to_device(st["prev_global"])
        self.prev_prev_global = ck.tree_to_device(st["prev_prev_global"])
        self.client_base = [ck.tree_to_device(t)
                            for t in st["client_base"]]
        self._buffer = [ck.tree_to_device(t) for t in st["buffer"]]
        self._buf_stale = list(st["buf_stale"])
        self._buf_recv = [0.0] * len(self._buffer)
        self.comm.__dict__.update(st["comm"])
        self.records = list(st["records"])
        if st["policy"] is not None:
            self.policy.set_state(st["policy"])
        self.sched.restore(st["sched"])
        self.accepted_by_client = np.asarray(
            st["accepted_by_client"], np.int64).copy()
        for k, v in st["counters"].items():
            setattr(self, {"duplicates": "duplicates",
                           "evictions": "evictions",
                           "readmissions": "readmissions",
                           "exchange_expired": "exchange_expired",
                           "wire_errors": "wire_errors",
                           "restarts": "restarts"}[k], int(v))
        if self.obs is not None and st["obs"] is not None:
            self.obs.metrics.restore(st["obs"])
        N = self.cfg.num_clients
        if fresh_clients:
            self.client_base = [self.global_params] * N
            self.model_version = np.full(N, self.server_version, int)
            self._last_seq = np.full(N, -1, np.int64)
            self._last_reply = {}
            self._pending = {}
        self._evicted = set()
        self.dead_reason = {}
        self._last_heard = np.full(N, time.monotonic())
        if self.obs is not None:
            self.obs.checkpoint(self.processed, h0, restored=True)

    # ------------------------------------------------------------ shutdown ---

    def finalize(self, drain_timeout: float = 1.0) -> RunResult:
        """Graceful drain + shutdown: process everything still queued,
        commit any partial buffer (no accepted update is ever lost),
        discard wedged two-phase exchanges through the failure hook,
        send final broadcasts, seal obs, build the ``RunResult``.
        Idempotent — the first call's result is returned thereafter."""
        if self._finalized is not None:
            return self._finalized
        if self.obs is not None:
            self.obs.sampler_stop()
        deadline = time.monotonic() + drain_timeout
        while self.processed < self.total_events:
            n = self.step(timeout=0.01)
            if n == 0 and time.monotonic() > deadline:
                break
        for i, (t, _carry, _deadline) in sorted(self._pending.items()):
            # a client accepted for upload never delivered its payload
            # (killed worker): discard, count the failure, move on
            if self.obs is not None:
                self.obs.failure(i, t)
        self._pending.clear()
        if self._buffer:
            self._flush(float(self.sched.now))
        for i in range(self.cfg.num_clients):
            self.transport.send_broadcast(
                i, BroadcastMsg(kind=wire.FINAL,
                                version=self.server_version))
        tr = self.transport    # last fault-stat drain before obs seals
        if hasattr(tr, "poll_fault_stats") and self.obs is not None:
            for kind, n in tr.poll_fault_stats().items():
                self.obs.fault(kind, n)
        res = RunResult(self.cfg.algorithm, self.records, self.comm,
                        self.cfg.target_acc).finalize_target()
        res = _finish_obs(_attach_sim_result(res, self.sched), self.obs)
        self._finalized = res
        return res
