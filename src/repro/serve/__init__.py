"""``repro.serve`` — the federation as a live service (docs/SERVING.md).

The closed-loop runtimes simulate asynchrony; this package *hosts* it:
real client workers (threads or processes) push versioned, compressed
uploads through a pluggable transport into a server hot loop that
drives the SAME algorithm/aggregator/codec objects — and, through the
determinism bridge (``driver="sequential"``, ``buffer_size=1``), yields
bit-identical results to the simulation.

    from repro.serve import serve_run
    res = serve_run(cfg, init_params_fn=..., loss_fn=...,
                    fed_data=data, evaluate_fn=...)

Transports live behind a string registry (``get_transport`` /
``register_transport``), mirroring ``repro.algorithms`` / ``repro.sim``.
"""
from repro.serve.client import (ClientCompute, ProcessClientWorker,
                                ScenarioPacer, SequentialDriver,
                                ThreadClientWorker)
from repro.serve.messages import (MAGIC, MAX_FRAME_BYTES, WIRE_SCHEMA,
                                  BroadcastMsg, UploadMsg, WireError,
                                  msg_from_wire, msg_to_wire)
from repro.serve.multitenant import MultiTenantServer
from repro.serve.run import launch_serving, resolve_live, serve_run
from repro.serve.server import FLServer
from repro.serve.transport import (ClientChannel, InprocTransport,
                                   Transport, available_transports,
                                   get_transport, register_transport)

__all__ = [
    "WIRE_SCHEMA", "MAGIC", "MAX_FRAME_BYTES", "WireError", "UploadMsg",
    "BroadcastMsg", "msg_to_wire",
    "msg_from_wire", "Transport", "ClientChannel", "InprocTransport",
    "get_transport", "register_transport", "available_transports",
    "FLServer", "ClientCompute", "ThreadClientWorker",
    "ProcessClientWorker", "SequentialDriver", "ScenarioPacer",
    "MultiTenantServer", "serve_run", "launch_serving", "resolve_live",
]
