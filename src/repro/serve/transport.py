"""Transport layer: how upload and broadcast messages move between
client workers and the ``FLServer``, behind a string registry mirroring
``repro.algorithms`` / ``repro.sim`` (``get_transport`` /
``register_transport``; builtins load lazily, a deliberate
pre-registration wins, accidental duplicates stay loud).

A :class:`Transport` owns one server-side upload queue (all clients
funnel into it — arrival order IS the serve-loop's event order) and one
broadcast mailbox per client.  Semantics every implementation must keep
(tests/test_serve.py):

* **per-client FIFO, no drops** — messages from one client arrive in
  the order it sent them (the two-phase report -> update exchange and
  staleness accounting depend on this); concurrent producers interleave
  arbitrarily but never lose or reorder a single client's stream;
* **backpressure** — the upload queue is bounded (``capacity``);
  ``ClientChannel.send`` blocks up to its timeout and returns False
  instead of dropping, so a slow server bounds queue depth rather than
  memory;
* **non-blocking server recv** — every server-side receive takes a
  timeout (the ``serve-blocking-in-hotloop`` analysis rule mechanically
  forbids indefinite blocking inside the drain loop).

Builtins: ``inproc`` (bounded ``queue.Queue`` pair — threads in one
process, zero serialization: trees and payloads pass by reference),
``socket`` (``repro.serve.socket_transport`` — localhost TCP with
magic-prefixed, length-bounded pickle frames for real client
processes) and ``chaos`` (``repro.resilience.chaos`` — a fault-
injecting wrapper around any inner transport, docs/RESILIENCE.md).
"""
from __future__ import annotations

import importlib
import queue
from typing import Callable, Dict, List, Optional, Tuple

from repro.serve.messages import UploadMsg


class ClientChannel:
    """One client's endpoint: send uploads, receive broadcasts."""

    def send(self, msg: UploadMsg, timeout: Optional[float] = None) -> bool:
        """Enqueue an upload.  Blocks up to ``timeout`` when the upload
        queue is full (backpressure); returns False instead of dropping
        on timeout."""
        raise NotImplementedError

    def recv(self, timeout: Optional[float] = None):
        """Next broadcast for this client, or None on timeout."""
        raise NotImplementedError

    def close(self) -> None:
        """Release the endpoint (sockets); idempotent."""


class Transport:
    """Server side of a transport (plus the client-channel factory)."""

    name: str = "transport"

    def recv_upload(self, timeout: Optional[float] = None
                    ) -> Optional[UploadMsg]:
        """Next upload in arrival order, or None on timeout."""
        raise NotImplementedError

    def drain_uploads(self, max_batch: int,
                      timeout: Optional[float] = None) -> List[UploadMsg]:
        """One serve-loop window: wait up to ``timeout`` for the first
        message, then take whatever is already queued (no extra waiting)
        up to ``max_batch``.  Default implementation on recv_upload."""
        first = self.recv_upload(timeout=timeout)
        if first is None:
            return []
        out = [first]
        while len(out) < max_batch:
            nxt = self.recv_upload(timeout=0)
            if nxt is None:
                break
            out.append(nxt)
        return out

    def queue_depth(self) -> int:
        """Uploads currently queued (approximate under concurrency)."""
        raise NotImplementedError

    def send_broadcast(self, client: int, msg) -> None:
        """Deliver a broadcast to one client's mailbox (never blocks:
        broadcast mailboxes are unbounded — the server must not wedge
        on a dead client)."""
        raise NotImplementedError

    def client_channel(self, client: int) -> ClientChannel:
        raise NotImplementedError

    def close(self) -> None:
        """Tear the transport down; idempotent."""


# ---------------------------------------------------------------- inproc ---

class _InprocChannel(ClientChannel):
    def __init__(self, transport: "InprocTransport", client: int):
        self._t = transport
        self._client = client

    def send(self, msg: UploadMsg, timeout: Optional[float] = None) -> bool:
        return self._t._put_upload(msg, timeout)

    def recv(self, timeout: Optional[float] = None):
        try:
            return self._t._bcast[self._client].get(
                timeout=timeout) if timeout else \
                self._t._bcast[self._client].get_nowait()
        except queue.Empty:
            return None


class InprocTransport(Transport):
    """Bounded in-process queue pair — the test/bench default.  Trees
    and payloads cross by reference (zero copies), which is exactly the
    closed-loop runtimes' aliasing (``client_params[i] = global_params``)
    so the determinism bridge stays bit-exact."""

    name = "inproc"

    def __init__(self, num_clients: int, capacity: int = 0):
        self._uploads: queue.Queue = queue.Queue(maxsize=capacity)
        self._bcast = [queue.Queue() for _ in range(num_clients)]
        self.num_clients = num_clients

    def _put_upload(self, msg: UploadMsg, timeout: Optional[float]) -> bool:
        import time
        msg.recv_host = time.monotonic()
        try:
            if timeout is None:
                self._uploads.put(msg)
            else:
                self._uploads.put(msg, timeout=timeout)
            return True
        except queue.Full:
            return False

    def recv_upload(self, timeout: Optional[float] = None
                    ) -> Optional[UploadMsg]:
        try:
            if timeout:
                return self._uploads.get(timeout=timeout)
            return self._uploads.get_nowait()
        except queue.Empty:
            return None

    def queue_depth(self) -> int:
        return self._uploads.qsize()

    def send_broadcast(self, client: int, msg) -> None:
        self._bcast[client].put(msg)

    def client_channel(self, client: int) -> ClientChannel:
        return _InprocChannel(self, client)


# -------------------------------------------------------------- registry ---

_REGISTRY: Dict[str, Callable[..., Transport]] = {}
_BUILTIN_OWNED: set = set()

_BUILTIN_FACTORIES: Tuple[Tuple[str, str, str], ...] = (
    # (name, module, attr) — modules import lazily on first lookup so
    # get_transport("inproc") never pays the socket machinery
    ("inproc", "repro.serve.transport", "InprocTransport"),
    ("socket", "repro.serve.socket_transport", "SocketTransport"),
    ("chaos", "repro.resilience.chaos", "ChaosTransport"),
)
_builtins_loaded = False


def _ensure_builtins() -> None:
    global _builtins_loaded
    if not _builtins_loaded:
        for name, mod, attr in _BUILTIN_FACTORIES:
            factory = getattr(importlib.import_module(mod), attr)
            # pre-registration wins: a plugin that deliberately took a
            # builtin name before the lazy load keeps it
            if name in _REGISTRY and name not in _BUILTIN_OWNED:
                continue
            _REGISTRY[name] = factory
            _BUILTIN_OWNED.add(name)
        _builtins_loaded = True


def register_transport(name: str, factory: Callable[..., Transport], *,
                       overwrite: bool = False) -> None:
    """Register a transport factory ``factory(num_clients, capacity=0)``
    under ``name``.  Re-registration is an error unless ``overwrite``
    (typo'd duplicates stay loud)."""
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"transport {name!r} already registered")
    _REGISTRY[name] = factory
    _BUILTIN_OWNED.discard(name)


def get_transport(name: str) -> Callable[..., Transport]:
    """Resolve a transport name to its factory; unknown names fail
    loudly with the registered set in the message."""
    _ensure_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown transport {name!r}; registered transports: "
            f"{', '.join(available_transports())}") from None


_PREFERRED = ("inproc", "socket", "chaos")


def available_transports() -> Tuple[str, ...]:
    """Registered names: builtins first (stable order), then third-party
    registrations in registration order."""
    _ensure_builtins()
    head = [n for n in _PREFERRED if n in _REGISTRY]
    return tuple(head) + tuple(n for n in _REGISTRY if n not in _PREFERRED)
