"""Client workers: the other end of a serve transport.

Three drivers share one compute bundle (:class:`ClientCompute` — the
SAME memoized jitted executables the closed-loop runtimes use, so a
serve run compiles nothing new):

* :class:`ThreadClientWorker` — a free-running thread per client:
  local round -> (report ->) upload -> download, repeatedly, optionally
  paced by a ``repro.sim`` speed model (:class:`ScenarioPacer`).
  Concurrency is real: arrival order at the server is whatever the
  threads produce.

* :class:`SequentialDriver` — the determinism bridge.  One thread owns
  every client AND pumps the server between sends, replicating the
  sequential event loop's RNG chain, scheduler arithmetic and encode
  seeds exactly — a ``buffer_size=1`` serve run through this driver is
  bit-identical to the closed-loop engines (tests/test_algorithms.py).

* :class:`ProcessClientWorker` — a spawned OS process talking to a
  ``socket`` transport (single-phase algorithms; loud error otherwise —
  the Eq. 1 value term needs the server-side eval set).

Wire discipline shared by all drivers: ``seq`` increments on every
message a client sends (the server asserts per-client FIFO on it), and
``version`` echoes the last download so the server's staleness metadata
can be cross-checked.
"""
from __future__ import annotations

import multiprocessing
import threading
import time
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.compress import ErrorFeedback, compress_update, get_codec
from repro.core.runtimes.common import (_enc_seed, _event_helpers,
                                        _tree_delta, _value_fn, _UPLOAD)
from repro.core.client import make_local_update
from repro.serve import messages as wire
from repro.serve.messages import BroadcastMsg, UploadMsg
from repro.serve.socket_transport import _SocketChannel


def _unstack(tree_s):
    return jax.tree.map(lambda x: x[0], tree_s)


class ClientCompute:
    """The per-client math, shared across workers in one process: the
    vmapped local update over size-1 stacks plus the lazily-built scalar
    helpers (Eq. 1 values / grad norms).  Routing through
    ``make_local_update`` / ``_event_helpers`` hits the closed-loop
    runtimes' memo caches, so serve and simulation share executables."""

    def __init__(self, *, loss_fn, local, data, num_clients,
                 client_eval_fn=None, sq_diff=None):
        self.local_update = make_local_update(loss_fn, local)
        self.data = {k: jnp.asarray(v) for k, v in data.items()}
        self._num_clients = num_clients
        self._client_eval_fn = client_eval_fn
        self._sq_diff = sq_diff
        self._helpers = None
        self._norms_only = None

    @classmethod
    def for_run(cls, run_cfg, *, loss_fn, fed_data, client_eval_fn=None):
        return cls(loss_fn=loss_fn, local=run_cfg.local,
                   data={"images": fed_data.images,
                         "labels": fed_data.labels,
                         "mask": fed_data.mask},
                   num_clients=run_cfg.num_clients,
                   client_eval_fn=client_eval_fn,
                   sq_diff=_value_fn(run_cfg))

    def helpers(self):
        if self._helpers is None:
            if self._client_eval_fn is None:
                raise ValueError(
                    "this worker's policy reads Eq. 1 values, which need "
                    "a client eval fn — pass client_eval_fn/evaluate_fn "
                    "to ClientCompute (process workers support "
                    "single-phase algorithms only)")
            self._helpers = _event_helpers(
                SimpleNamespace(num_clients=self._num_clients),
                self._client_eval_fn, self._sq_diff)
        return self._helpers

    def local_round(self, params, i, urng):
        """One client's local round as a size-1 stacked dispatch; returns
        (stacked new params, stacked effective gradient)."""
        one = jax.tree.map(lambda x: x[None], params)
        d_i = {k: v[i:i + 1] for k, v in self.data.items()}
        newp_s, eff_s, _ = self.local_update(one, d_i, urng)
        return newp_s, eff_s

    def value(self, newp_s, eff_s, prev_grad) -> float:
        """Eq. 1 V for this round (policies with ``needs_values``) —
        the exact closed-loop arithmetic including the zeros prev-grad
        bootstrap on a client's first round."""
        batch_eval, values_fn, _ = self.helpers()
        accs = batch_eval(newp_s)
        pg = (prev_grad if prev_grad is not None
              else jax.tree.map(jnp.zeros_like, _unstack(eff_s)))
        pg_s = jax.tree.map(lambda x: x[None], pg)
        return float(values_fn(pg_s, eff_s, accs)[0])

    def norm(self, eff_s) -> float:
        if self._client_eval_fn is not None:
            return float(self.helpers()[2](eff_s)[0])
        # norm-only worker (process path): no eval fn required, so skip
        # the full helper set and jit the norm alone (once)
        if self._norms_only is None:
            from repro.common.pytree import tree_sq_norm
            self._norms_only = jax.jit(jax.vmap(tree_sq_norm))
        return float(self._norms_only(eff_s)[0])


class ScenarioPacer:
    """Paces free-running workers from a ``repro.sim`` speed model: each
    round draws the client's simulated service time, advances that
    client's sim clock (the ``sim_time`` it stamps on uploads) and —
    when ``time_scale > 0`` — sleeps ``time_scale`` host-seconds per
    simulated second (capped) so traffic *shape* follows the scenario
    without replaying it in real time."""

    def __init__(self, speed, time_scale: float = 0.0,
                 max_sleep: float = 0.25):
        self.speed = speed
        self.time_scale = time_scale
        self.max_sleep = max_sleep
        self._t = {}

    def advance(self, client: int) -> float:
        t0 = self._t.get(client, 0.0)
        service = float(self.speed.sample(client, t0))
        self._t[client] = t0 + service
        if self.time_scale > 0:
            time.sleep(min(service * self.time_scale, self.max_sleep))
        return self._t[client]


# ------------------------------------------------------- worker loop ---

def _recv_ctrl(channel, timeout: float, stop=None, skip_init: bool = False):
    """Wait for the server's next broadcast, polling so a stop flag (or
    a dead server) can break the wait; None on deadline.  ``skip_init``
    drops stray mid-run INIT frames (a server that re-admitted this
    client as fresh) instead of returning them as an exchange reply."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if stop is not None and stop.is_set():
            return None
        msg = channel.recv(timeout=0.05)
        if msg is not None:
            if skip_init and msg.kind == wire.INIT:
                continue
            return msg
    return None


def _exchange(channel, msg, *, recv_timeout: float, stop=None,
              retry=None, stats=None):
    """One stop-and-wait exchange: send ``msg``, wait for its reply.

    Without a :class:`~repro.resilience.RetryPolicy` this is a single
    send + wait (the pre-resilience behavior).  With one, the SAME
    frame (same ``seq``) is re-sent with exponential backoff + seeded
    jitter whenever the per-attempt reply wait times out — the server
    dedups by ``(client, seq)`` and replays its cached reply, so
    at-least-once sending composes into exactly-once processing.
    Replies are matched on ``ack_seq``: a stale reply from an earlier
    attempt of a PREVIOUS exchange (the original arrived late, after
    its retry was already answered) is discarded, not misread as this
    exchange's answer.  Returns the reply, or None on exhaustion."""
    attempts = 1 if retry is None else retry.max_attempts
    wait = recv_timeout if retry is None else retry.attempt_timeout_s
    for attempt in range(1, attempts + 1):
        if stop is not None and stop.is_set():
            return None
        if attempt > 1:
            if stats is not None:
                stats["retries"] = stats.get("retries", 0) + 1
            time.sleep(retry.backoff(attempt - 1, msg.client, msg.seq))
        if not channel.send(msg, timeout=recv_timeout):
            continue                   # backpressure deadline: retry
        deadline = time.monotonic() + wait
        while True:
            left = deadline - time.monotonic()
            if left <= 0:
                break
            reply = _recv_ctrl(channel, left, stop, skip_init=True)
            if reply is None:
                break
            if (reply.kind in (wire.DECISION, wire.DOWNLOAD)
                    and reply.ack_seq >= 0 and reply.ack_seq != msg.seq):
                continue               # stale reply: keep waiting
            return reply
    return None


def _client_loop(compute: ClientCompute, channel, client: int, *,
                 data_index: Optional[int] = None, pacer=None,
                 rounds: Optional[int] = None, recv_timeout: float = 30.0,
                 stop=None, retry=None, stats=None) -> int:
    """The free-running client body shared by thread and process
    workers; returns the number of completed rounds.  ``retry`` (a
    ``repro.resilience.RetryPolicy``) makes every exchange survive
    lost frames and lost replies; ``stats`` (a dict) accumulates the
    retry count for end-of-run reconciliation."""
    init = _recv_ctrl(channel, recv_timeout, stop)
    if init is None or init.kind != wire.INIT:
        return 0
    meta = init.meta
    params = init.tree
    di = client if data_index is None else data_index
    seed_cfg = SimpleNamespace(seed=meta["seed"])
    codec = get_codec(meta["compressor"])
    ef = ErrorFeedback(enabled=meta["error_feedback"])
    # per-client RNG stream: free workers fold their id into the run key
    # (independent streams, no cross-thread coordination; the sequential
    # driver replicates the closed-loop global chain instead)
    rng = jax.random.fold_in(jax.random.key(meta["seed"]), client)
    prev_grad = None
    version = int(init.version)   # 0 on a fresh run; the restored
    #                               server version after a resume
    seq = 0
    t0 = time.monotonic()
    total = rounds if rounds is not None else int(meta["rounds"])
    r = 0
    while r < total and not (stop is not None and stop.is_set()):
        rng, urng = jax.random.split(rng)
        sim_t = (pacer.advance(client) if pacer is not None
                 else time.monotonic() - t0)
        newp_s, eff_s = compute.local_round(params, di, urng)
        value = norm = None
        if meta["needs_values"]:
            value = compute.value(newp_s, eff_s, prev_grad)
        if meta["needs_norms"]:
            norm = compute.norm(eff_s)
        reply = None
        if meta["two_phase"]:
            reply = _exchange(channel, UploadMsg(
                kind=wire.REPORT, client=client, seq=seq,
                version=version, sim_time=sim_t, value=value, norm=norm),
                recv_timeout=recv_timeout, stop=stop, retry=retry,
                stats=stats)
            seq += 1
            if reply is None or reply.kind == wire.FINAL:
                break
        if reply is None or reply.kind == wire.DECISION:
            newp = _unstack(newp_s)
            if codec.is_identity:
                payload, enc_seed = newp, 0
            else:
                # free workers seed the encoder from their OWN round
                # counter (the closed loop's global event counter doesn't
                # exist under concurrency); deterministic per client
                enc_seed = _enc_seed(seed_cfg, r, client, _UPLOAD)
                payload, _ = compress_update(
                    codec, ef, client, _tree_delta(newp, params),
                    seed=enc_seed)
            reply = _exchange(channel, UploadMsg(
                kind=wire.UPDATE, client=client, seq=seq,
                version=version, sim_time=sim_t, codec=codec.name,
                payload=payload, enc_seed=enc_seed),
                recv_timeout=recv_timeout, stop=stop, retry=retry,
                stats=stats)
            seq += 1
        if reply is None or reply.kind == wire.FINAL:
            break
        if reply.kind != wire.DOWNLOAD:
            raise RuntimeError(f"protocol violation: expected download, "
                               f"got {reply.kind!r}")
        params = reply.tree
        version = reply.version
        prev_grad = _unstack(eff_s)
        r += 1
    channel.close()
    return r


class ThreadClientWorker(threading.Thread):
    """One client as a daemon thread over any transport's channel."""

    def __init__(self, compute: ClientCompute, channel, client: int, *,
                 pacer=None, rounds: Optional[int] = None,
                 recv_timeout: float = 30.0, retry=None):
        super().__init__(daemon=True, name=f"serve-client-{client}")
        self.client = client
        self.completed = 0
        self.stats = {"retries": 0}   # reconciled by the chaos soak
        self._kw = dict(pacer=pacer, rounds=rounds,
                        recv_timeout=recv_timeout, retry=retry,
                        stats=self.stats)
        self._compute, self._channel = compute, channel
        # NOT "_stop": threading.Thread owns that name internally
        self._stop_evt = threading.Event()

    def stop(self) -> None:
        self._stop_evt.set()

    def run(self) -> None:
        self.completed = _client_loop(self._compute, self._channel,
                                      self.client, stop=self._stop_evt,
                                      **self._kw)


# ------------------------------------------------- sequential driver ---

class SequentialDriver:
    """The determinism bridge: one thread plays every client in the
    scheduler's completion order and pumps ``server.step()`` between
    sends, so a ``buffer_size=1`` serve run is bit-identical to the
    sequential closed-loop engine (same RNG chain, same encode seeds,
    same scheduler arithmetic — tests/test_algorithms.py asserts it).

    The driver owns the :class:`EventScheduler` (build the server with
    ``sched=driver_sched, account_bytes=False``) and bills each event's
    actual wire bytes into it exactly where the closed loop does."""

    def __init__(self, server, compute: ClientCompute):
        if server._account_bytes:
            raise ValueError(
                "SequentialDriver bills the scheduler itself — build the "
                "FLServer with account_bytes=False and a shared sched")
        self.server = server
        self.compute = compute

    def _pump_recv(self, channel):
        """Alternate server.step() with channel polls until the reply
        lands (single-threaded: the reply is deterministic and queued)."""
        for _ in range(1000):
            msg = channel.recv(timeout=0)
            if msg is not None:
                return msg
            self.server.step(timeout=0)
        raise RuntimeError("serve exchange wedged: no reply after the "
                           "server drained its queue (transport bug?)")

    def run(self) -> "RunResult":
        server, compute = self.server, self.compute
        cfg = server.cfg
        N = cfg.num_clients
        transport = server.transport
        channels = [transport.client_channel(i) for i in range(N)]
        start_ev = server.processed
        if start_ev:
            # a resumed server (restore_checkpoint(fresh_clients=False)):
            # the driver reconstructs every client's live state from the
            # server's checkpointed view — params from the per-client
            # decode base (exactly the tree each client last downloaded),
            # versions and seq watermarks from the server's records —
            # instead of the init broadcast, then replays the global RNG
            # chain up to the checkpoint.  Continuation is bit-equal to
            # the uninterrupted run (tests/test_resilience.py).
            if server.policy.needs_values:
                raise ValueError(
                    "bit-equal bridge resume needs a policy without "
                    "needs_values — per-client prev-grad state lives "
                    "client-side and is not in the server checkpoint")
            codec = get_codec(cfg.compressor)
            if not codec.is_identity and cfg.error_feedback:
                raise ValueError(
                    "bit-equal bridge resume with a codec needs "
                    "error_feedback=False — EF residuals live "
                    "client-side and are not in the server checkpoint")
            meta = {"needs_values": server.policy.needs_values,
                    "needs_norms": server.policy.needs_norms,
                    "two_phase": server.two_phase,
                    "compressor": cfg.compressor,
                    "error_feedback": cfg.error_feedback}
            ef = ErrorFeedback(enabled=cfg.error_feedback)
            params = [server.client_base[i] for i in range(N)]
            versions = [int(v) for v in server.model_version]
            seqs = [int(s) + 1 for s in server._last_seq]
        else:
            server.start()
            inits = [self._pump_recv(ch) for ch in channels]
            meta = inits[0].meta
            params = [init.tree for init in inits]
            codec = get_codec(meta["compressor"])
            ef = ErrorFeedback(enabled=meta["error_feedback"])
            versions = [0] * N
            seqs = [0] * N
        prev_grads = [None] * N
        sched = server.sched
        # the driver owns checkpoint cadence: the server's own save fires
        # inside _finish_event, BEFORE this loop bills the event's bytes
        # into the scheduler — a snapshot taken there is missing the last
        # reschedule and would not resume bit-equal.  Defer every save to
        # after sched.schedule() below.
        ckpt_every, server._ckpt_every = server._ckpt_every, 0
        # the closed loop's exact RNG chain: key(seed) split once for
        # init (the server used the same derivation), then once per event
        rng, _krng = jax.random.split(jax.random.key(cfg.seed))
        for _ in range(start_ev):
            rng, _ = jax.random.split(rng)
        for ev in range(start_ev, server.total_events):
            t_now, i = sched.pop()
            u0, d0 = server.comm.uplink_bytes, server.comm.downlink_bytes
            rng, urng = jax.random.split(rng)
            newp_s, eff_s = compute.local_round(params[i], i, urng)
            value = norm = None
            if meta["needs_values"]:
                value = compute.value(newp_s, eff_s, prev_grads[i])
            if meta["needs_norms"]:
                norm = compute.norm(eff_s)
            ch = channels[i]
            reply = None
            if meta["two_phase"]:
                ch.send(UploadMsg(kind=wire.REPORT, client=i, seq=seqs[i],
                                  version=versions[i], sim_time=t_now,
                                  value=value, norm=norm))
                seqs[i] += 1
                reply = self._pump_recv(ch)
            if reply is None or reply.kind == wire.DECISION:
                newp = _unstack(newp_s)
                if codec.is_identity:
                    payload, enc_seed = newp, 0
                else:
                    # the GLOBAL event counter seeds the encoder — the
                    # bit-exactness hinge vs the closed loop
                    enc_seed = _enc_seed(cfg, ev, i, _UPLOAD)
                    payload, _ = compress_update(
                        codec, ef, i, _tree_delta(newp, params[i]),
                        seed=enc_seed)
                ch.send(UploadMsg(kind=wire.UPDATE, client=i, seq=seqs[i],
                                  version=versions[i], sim_time=t_now,
                                  codec=codec.name, payload=payload,
                                  enc_seed=enc_seed))
                seqs[i] += 1
                reply = self._pump_recv(ch)
            if reply.kind != wire.DOWNLOAD:
                raise RuntimeError(f"protocol violation: expected "
                                   f"download, got {reply.kind!r}")
            params[i] = reply.tree
            versions[i] = reply.version
            prev_grads[i] = _unstack(eff_s)
            # the round's actual wire bytes reschedule the client — the
            # exact closed-loop call (byte-aware network models included)
            sched.schedule(i, upload_bytes=server.comm.uplink_bytes - u0,
                           download_bytes=server.comm.downlink_bytes - d0)
            if ckpt_every and server.processed % ckpt_every == 0:
                server.save_checkpoint()
        return server.finalize()


# --------------------------------------------------- process workers ---

def _process_client_main(host, port, client, forward_fn, model_cfg, local,
                         images, labels, mask, rounds, pace_seed):
    """Entry point of a spawned client process (module-level so the
    spawn pickler can import it).  Rebuilds the compute bundle from
    numpy inputs; single-phase algorithms only (no eval set here)."""
    from repro.core.client import make_weighted_classifier_loss
    loss_fn = make_weighted_classifier_loss(forward_fn, model_cfg)
    compute = ClientCompute(
        loss_fn=loss_fn, local=local,
        data={"images": images, "labels": labels, "mask": mask},
        num_clients=1)
    pacer = None
    if pace_seed is not None:
        from repro.core.scheduler import SpeedModel
        pacer = ScenarioPacer(SpeedModel.paper_testbed(client + 1,
                                                       pace_seed))
    channel = _SocketChannel(host, port, client)
    _client_loop(compute, channel, client, data_index=0, pacer=pacer,
                 rounds=rounds)


class ProcessClientWorker:
    """One client as an OS process over the ``socket`` transport.  The
    child rebuilds its jits from picklable pieces (forward fn by module
    reference, model/local dataclasses, its own data rows as numpy) —
    so only registry-style models travel; single-phase algorithms only
    (the Eq. 1 value term needs the server's eval set)."""

    def __init__(self, address, client: int, *, forward_fn, model_cfg,
                 local, fed_data, rounds: Optional[int] = None,
                 pace_seed: Optional[int] = None):
        import numpy as np
        host, port = address
        sl = slice(client, client + 1)
        self._proc = multiprocessing.get_context("spawn").Process(
            target=_process_client_main,
            args=(host, port, client, forward_fn, model_cfg, local,
                  np.asarray(fed_data.images[sl]),
                  np.asarray(fed_data.labels[sl]),
                  np.asarray(fed_data.mask[sl]), rounds, pace_seed),
            daemon=True, name=f"serve-client-{client}")
        self.client = client

    def start(self) -> None:
        self._proc.start()

    def join(self, timeout: Optional[float] = None) -> None:
        self._proc.join(timeout)

    def kill(self) -> None:
        """Hard-kill the worker (the killed-client transport test)."""
        self._proc.kill()

    @property
    def exitcode(self):
        return self._proc.exitcode
