"""Multi-tenant serving: several federations sharing one device mesh.

``FLServer.step()`` processes one window and returns without blocking,
so a host can interleave any number of independent federations in one
thread: round-robin the servers, sleep only when EVERY queue is quiet.
Tenants with the same model/local-spec share jitted executables through
``make_local_update``'s memo cache — the second tenant compiles
nothing.

    mt = MultiTenantServer([server_a, server_b])
    results = mt.run()        # [RunResult, RunResult] in tenant order

Each tenant keeps its own transport, algorithm state, CommStats and obs
— nothing is shared but the device and the loop.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core.metrics import RunResult

_IDLE_SLEEP = 0.002


class MultiTenantServer:
    """Round-robin executor over independent :class:`FLServer`\\ s."""

    def __init__(self, servers: Sequence):
        if not servers:
            raise ValueError("MultiTenantServer needs at least one server")
        self.servers = list(servers)
        self._stopping = False

    def stop(self) -> None:
        self._stopping = True

    def start(self) -> None:
        for s in self.servers:
            s.start()

    def run(self, stall_timeout: float = 60.0) -> List[RunResult]:
        """Interleave every tenant's windows until all federations hit
        their event totals (or the whole fleet stalls); returns each
        tenant's finalized ``RunResult`` in construction order."""
        last_msg = time.monotonic()
        while not self._stopping:
            live = [s for s in self.servers
                    if s.processed < s.total_events]
            if not live:
                break
            drained = 0
            for s in live:
                drained += s.step(timeout=0)
            if drained:
                last_msg = time.monotonic()
            else:
                if time.monotonic() - last_msg > stall_timeout:
                    break
                time.sleep(_IDLE_SLEEP)
        return [s.finalize() for s in self.servers]
