"""Multi-tenant serving: several federations sharing one device mesh.

``FLServer.step()`` processes one window and returns without blocking,
so a host can interleave any number of independent federations in one
thread: round-robin the servers, sleep only when EVERY queue is quiet.
Tenants with the same model/local-spec share jitted executables through
``make_local_update``'s memo cache — the second tenant compiles
nothing.

    mt = MultiTenantServer([server_a, server_b])
    results = mt.run()        # [RunResult, RunResult] in tenant order

Each tenant keeps its own transport, algorithm state, CommStats and obs
— nothing is shared but the device and the loop.
"""
from __future__ import annotations

import time
from typing import List, Sequence

from repro.core.metrics import RunResult

_IDLE_SLEEP = 0.002


class MultiTenantServer:
    """Round-robin executor over independent :class:`FLServer`\\ s."""

    def __init__(self, servers: Sequence, *, live=None):
        if not servers:
            raise ValueError("MultiTenantServer needs at least one server")
        self.servers = list(servers)
        self._stopping = False
        # the live telemetry plane (repro.obs.live): ONE HTTP endpoint
        # over every tenant, each labelled tenant="<server.name>" in the
        # /metrics exposition; built on start(), stopped after run()
        self._live_req = live
        self.live = None

    def stop(self) -> None:
        self._stopping = True

    def start(self) -> None:
        if self._live_req and self.live is None:
            from repro.serve.run import resolve_live
            self.live = resolve_live(self._live_req, self.servers)
        for s in self.servers:
            s.start()

    def run(self, stall_timeout: float = 60.0) -> List[RunResult]:
        """Interleave every tenant's windows until all federations hit
        their event totals (or the whole fleet stalls); returns each
        tenant's finalized ``RunResult`` in construction order."""
        for s in self.servers:     # opt-in live metric samplers
            if s.obs is not None:
                s.obs.sampler_start()
        last_msg = time.monotonic()
        while not self._stopping:
            active = [s for s in self.servers
                      if s.processed < s.total_events]
            if not active:
                break
            drained = 0
            for s in active:
                drained += s.step(timeout=0)
            if drained:
                last_msg = time.monotonic()
            else:
                if time.monotonic() - last_msg > stall_timeout:
                    break
                time.sleep(_IDLE_SLEEP)
        try:
            return [s.finalize() for s in self.servers]
        finally:
            if self.live is not None:
                self.live.stop()
                self.live = None
