"""``serve_run`` — one live serve run, batteries included.

Mirrors ``run_event_driven``'s signature (config + the four callables)
and returns the same ``RunResult``, so switching an experiment from
simulation to service is a one-line change:

    res = serve_run(cfg, init_params_fn=..., loss_fn=...,
                    fed_data=data, evaluate_fn=...)

Drivers:

* ``driver="thread"`` (default) — one free-running thread per client,
  real concurrency, arrival order is whatever the fleet produces.
* ``driver="sequential"`` — the determinism bridge: one thread plays
  every client in scheduler order; with ``buffer_size=1`` the result is
  bit-identical to the closed-loop engines.

``launch_serving`` returns the un-started pieces (server + workers) for
callers composing their own lifecycles (multi-tenant, benchmarks).
"""
from __future__ import annotations

from typing import Optional

from repro.core.metrics import RunResult
from repro.core.scheduler import SpeedModel
from repro.serve.client import (ClientCompute, ScenarioPacer,
                                SequentialDriver, ThreadClientWorker)
from repro.serve.server import FLServer
from repro.serve.transport import Transport, get_transport

DRIVERS = ("thread", "sequential")


def resolve_live(live, servers):
    """Normalise a user-facing ``live=`` value into a STARTED
    ``ObsHttpServer`` over ``servers`` (or None): True — defaults
    (127.0.0.1, ephemeral port), an int — that port, a dict —
    ``ObsHttpServer`` kwargs (host/port/probes).  The plane is attached
    to each server as ``.live`` so callers holding only the server (or
    the RunResult path) can find the bound port."""
    if live is None or live is False:
        return None
    if live is True:
        kw = {}
    elif isinstance(live, int):
        kw = {"port": live}
    elif isinstance(live, dict):
        kw = dict(live)
    else:
        raise ValueError(
            "live must be None/False (off), True (ephemeral port), an "
            f"int port, or a dict of ObsHttpServer kwargs; got {live!r}")
    from repro.obs.live import ObsHttpServer
    plane = ObsHttpServer(servers, **kw).start()
    for s in servers:
        s.live = plane
    return plane


def _resolve_transport(transport, num_clients: int, capacity: int):
    if isinstance(transport, Transport):
        return transport, False
    return get_transport(transport)(num_clients, capacity), True


def _resolve_pacer(pace, run_cfg):
    """``pace``: None (free-run), True (the run's scenario compute fleet,
    paper_testbed when none), a SpeedModel, or a ready ScenarioPacer."""
    if pace is None or pace is False:
        return None
    if isinstance(pace, ScenarioPacer):
        return pace
    if pace is True:
        from repro.core.runtimes.common import _scenario_models
        compute, _, _ = _scenario_models(run_cfg, run_cfg.num_clients)
        pace = compute or SpeedModel.paper_testbed(run_cfg.num_clients,
                                                   run_cfg.seed)
    return ScenarioPacer(pace)


def launch_serving(run_cfg, *, init_params_fn, loss_fn, fed_data,
                   evaluate_fn, client_eval_fn=None, transport="inproc",
                   capacity: int = 0, pace=None, speed=None,
                   rounds: Optional[int] = None,
                   recv_timeout: float = 30.0, retry=None,
                   exchange_timeout: Optional[float] = None,
                   liveness_timeout: Optional[float] = None,
                   verbose: bool = False, name: str = "default"):
    """Build (but do not start) one federation's serving pieces:
    ``(server, workers, transport)``.  The caller owns the lifecycle:
    ``server.start()``, start the workers, then ``server.run()`` or
    compose ``server.step()`` into a larger loop (multi-tenant).

    Resilience knobs (docs/RESILIENCE.md): ``retry`` — a
    ``repro.resilience.RetryPolicy`` for every client's exchanges;
    ``exchange_timeout`` / ``liveness_timeout`` — the server's
    per-exchange and dead-client deadlines (seconds; None = off).
    ``name`` is the tenant label the live telemetry plane
    (docs/OBSERVABILITY.md) tags this federation with."""
    tr, _owned = _resolve_transport(transport, run_cfg.num_clients,
                                    capacity)
    server = FLServer(run_cfg, init_params_fn=init_params_fn,
                      evaluate_fn=evaluate_fn, transport=tr, speed=speed,
                      exchange_timeout=exchange_timeout,
                      liveness_timeout=liveness_timeout,
                      verbose=verbose, name=name)
    compute = ClientCompute.for_run(
        run_cfg, loss_fn=loss_fn, fed_data=fed_data,
        client_eval_fn=client_eval_fn or evaluate_fn)
    pacer = _resolve_pacer(pace, run_cfg)
    workers = [ThreadClientWorker(compute, tr.client_channel(i), i,
                                  pacer=pacer, rounds=rounds,
                                  recv_timeout=recv_timeout, retry=retry)
               for i in range(run_cfg.num_clients)]
    return server, workers, tr


def serve_run(run_cfg, *, init_params_fn, loss_fn, fed_data, evaluate_fn,
              client_eval_fn=None, transport="inproc",
              driver: str = "thread", capacity: int = 0, pace=None,
              speed=None, stall_timeout: float = 60.0,
              recv_timeout: float = 30.0, retry=None,
              exchange_timeout: Optional[float] = None,
              liveness_timeout: Optional[float] = None,
              verbose: bool = False, live=None) -> RunResult:
    """Run one federation as a live service and return its RunResult.

    ``live`` turns on the HTTP telemetry plane for the run's duration
    (True / port / dict — see ``resolve_live``); the bound plane is
    reachable as ``server.live`` while the run is up."""
    if driver not in DRIVERS:
        raise ValueError(f"unknown driver {driver!r}; known: {DRIVERS}")
    if driver == "sequential":
        if live:
            raise ValueError(
                "live telemetry needs the thread driver — the "
                "sequential bridge runs in one thread with nothing to "
                "watch concurrently")
        tr, owned = _resolve_transport(transport, run_cfg.num_clients,
                                       capacity)
        # resume_fresh_clients=False: the bridge driver reconstructs each
        # client's exact state (base tree, version, seq) from the restored
        # server, so a cfg.resume run continues bit-identically.
        server = FLServer(run_cfg, init_params_fn=init_params_fn,
                          evaluate_fn=evaluate_fn, transport=tr,
                          speed=speed, account_bytes=False,
                          resume_fresh_clients=False,
                          verbose=verbose)
        compute = ClientCompute.for_run(
            run_cfg, loss_fn=loss_fn, fed_data=fed_data,
            client_eval_fn=client_eval_fn or evaluate_fn)
        try:
            return SequentialDriver(server, compute).run()
        finally:
            if owned:
                tr.close()
    server, workers, tr = launch_serving(
        run_cfg, init_params_fn=init_params_fn, loss_fn=loss_fn,
        fed_data=fed_data, evaluate_fn=evaluate_fn,
        client_eval_fn=client_eval_fn, transport=transport,
        capacity=capacity, pace=pace, speed=speed,
        recv_timeout=recv_timeout, retry=retry,
        exchange_timeout=exchange_timeout,
        liveness_timeout=liveness_timeout, verbose=verbose)
    plane = None
    try:
        plane = resolve_live(live, [server])
        server.start()
        for w in workers:
            w.start()
        res = server.run(stall_timeout=stall_timeout)
        for w in workers:
            w.stop()
        for w in workers:
            w.join(timeout=5.0)
        # fold client-side stats (retry counts) into the sealed metrics
        # — the counters the chaos soak reconciles live on the result
        server.absorb_client_stats(workers)
        return res
    finally:
        if plane is not None:
            plane.stop()
        tr.close()
