"""The serve wire protocol (schema ``serve-wire/v1``).

Two message families cross a transport:

* ``UploadMsg`` — client -> server.  ``kind="report"`` carries the
  policy's declared scalars (Eq. 1 value / gradient norm) so the SERVER
  makes the ship/skip decision with exact policy state (VAFL's
  above-mean gate is fleet-wide — no client can evaluate it alone);
  ``kind="update"`` carries the model payload of an accepted upload
  (a :class:`repro.compress.Payload` delta under a codec, the full
  parameter tree under identity).

* ``BroadcastMsg`` — server -> client.  ``kind="init"`` bootstraps a
  client (initial model + the run flags it needs: which scalars to
  compute, whether the exchange is two-phase); ``kind="decision"``
  answers a report (two-phase algorithms only); ``kind="download"``
  closes every event with the latest global model; ``kind="final"``
  tells free-running clients to stop.

The two-phase exchange mirrors the paper's protocol: a 4-byte scalar
report precedes each decision, and the heavy model payload only ships
when the server says so — which is exactly what ``CommStats`` has
always accounted (reports cost 4 B; declined events cost no payload).
Decision frames themselves are control-plane traffic and are NOT billed,
matching the closed-loop runtimes where the decision is a function call.

Everything in a message is either a scalar, a ``Payload`` (numpy planes
+ picklable treedef meta) or a parameter pytree — the socket transport
pickles messages whole after converting tree leaves to numpy
(:func:`tree_to_host`).

Frame format: 4 magic bytes (``MAGIC`` — format version, cheap
corruption tripwire) + 4-byte big-endian length + pickled body, with
the length bounded by ``MAX_FRAME_BYTES``.  A frame that fails the
magic or size check raises :class:`WireError` (a ``ConnectionError``
subclass) so transports route it through their structured dead-client
path instead of a blind ``pickle.UnpicklingError`` killing a reader
thread (docs/RESILIENCE.md).
"""
from __future__ import annotations

import pickle
import struct
from dataclasses import dataclass, field
from typing import Any, Optional

WIRE_SCHEMA = "serve-wire/v1"

# frame-format magic: four bytes every frame starts with.  Bumping the
# frame layout bumps this; a stream that desyncs (corruption, a
# truncated frame followed by more bytes) trips it immediately.
MAGIC = b"RFL1"

# hard bound on one frame's body.  Generous — a full float32 model tree
# for this repo's zoo is well under it — but it turns a corrupted
# length prefix (reading 3 GB because four bytes flipped) into a
# structured WireError instead of an allocation stampede.
MAX_FRAME_BYTES = 1 << 28      # 256 MiB


class WireError(ConnectionError):
    """A frame failed the wire-format checks (bad magic, oversized
    length, undecodable body).  Subclasses ``ConnectionError`` because
    the stream is unusable past the bad frame — transports treat the
    peer as dead (reason ``"wire-error"``) and surface it to the
    server's liveness tracker."""

# UploadMsg kinds
REPORT = "report"
UPDATE = "update"
# BroadcastMsg kinds
INIT = "init"
DECISION = "decision"
DOWNLOAD = "download"
FINAL = "final"


@dataclass
class UploadMsg:
    """One client -> server message.

    ``version`` is the global-model version the client last downloaded
    (its training base — the server's staleness metadata and, under a
    codec, the delta's reference).  ``seq`` is the client's own event
    counter (per-client FIFO is asserted on it), ``sim_time`` the
    client's clock (scenario-paced simulated seconds, or host seconds
    for free-running workers).  ``recv_host`` is stamped by the
    transport when the message lands server-side — the commit-latency
    clock, deliberately single-domain."""
    kind: str                      # REPORT | UPDATE
    client: int
    seq: int
    version: int
    sim_time: float = 0.0
    value: Optional[float] = None  # Eq. 1 V (policies with needs_values)
    norm: Optional[float] = None   # ||eff_grad||^2 (needs_norms)
    codec: str = "identity"
    payload: Any = None            # Payload (codec) | param tree (identity)
    enc_seed: int = 0              # the payload's deterministic encode seed
    recv_host: float = 0.0         # transport-stamped server arrival time


@dataclass
class BroadcastMsg:
    """One server -> client message (init / decision / download / final).

    ``ack_seq`` echoes the upload ``seq`` a decision/download answers,
    so a retrying client can discard a stale extra reply (its original
    reply arriving after the retry already got the replay) instead of
    consuming it as the NEXT exchange's answer; -1 on unsolicited
    frames (init / final)."""
    kind: str
    version: int = 0
    tree: Any = None               # model pytree (init / download)
    upload: bool = False           # DECISION: ship the payload?
    meta: dict = field(default_factory=dict)   # INIT: run flags
    ack_seq: int = -1              # the upload seq this frame answers


def tree_to_host(tree):
    """Map a pytree's leaves to numpy so it pickles across processes
    (jax.Array pickling is version-dependent; numpy is forever).  The
    float bits are preserved exactly, so a socket hop never perturbs
    golden-seed parity."""
    import jax
    import numpy as np
    if tree is None:
        return None
    return jax.tree.map(lambda x: np.asarray(x), tree)


def msg_to_wire(msg) -> bytes:
    """Pickle one message into a 4-byte length-prefixed frame."""
    if isinstance(msg, BroadcastMsg) and msg.tree is not None:
        msg = BroadcastMsg(kind=msg.kind, version=msg.version,
                           tree=tree_to_host(msg.tree), upload=msg.upload,
                           meta=msg.meta, ack_seq=msg.ack_seq)
    elif isinstance(msg, UploadMsg) and msg.payload is not None:
        from repro.compress.base import Payload
        if not isinstance(msg.payload, Payload):   # identity: raw tree
            msg = UploadMsg(**{**msg.__dict__,
                               "payload": tree_to_host(msg.payload)})
    body = pickle.dumps((WIRE_SCHEMA, msg), protocol=pickle.HIGHEST_PROTOCOL)
    if len(body) > MAX_FRAME_BYTES:
        raise WireError(f"frame body of {len(body)} bytes exceeds "
                        f"MAX_FRAME_BYTES ({MAX_FRAME_BYTES})")
    return MAGIC + struct.pack("!I", len(body)) + body


def msg_from_wire(body: bytes):
    """Decode one frame body (magic + length prefix already consumed).
    An undecodable body — corruption that kept a plausible header —
    raises WireError; a well-formed body from an incompatible peer
    raises ValueError (schema mismatch)."""
    try:
        schema, msg = pickle.loads(body)
    except Exception as e:                    # noqa: BLE001 — any pickle
        # failure here means corrupt bytes; fold into the wire path
        raise WireError(f"undecodable frame body: {e}") from e
    if schema != WIRE_SCHEMA:
        raise ValueError(f"wire schema mismatch: got {schema!r}, "
                         f"expected {WIRE_SCHEMA!r}")
    return msg


def read_frame(sock) -> Optional[bytes]:
    """Read one framed body from a socket; None on clean EOF (peer
    closed between frames).  A half-read frame — the peer died
    mid-send — raises ConnectionError; bad magic or an oversized length
    raises WireError.  Either way the transport turns it into the
    structured dead-client path."""
    head = _read_exact(sock, len(MAGIC) + 4)
    if head is None:
        return None
    if head[:len(MAGIC)] != MAGIC:
        raise WireError(f"bad frame magic {head[:len(MAGIC)]!r} "
                        f"(expected {MAGIC!r}) — corrupt or desynced "
                        "stream")
    (n,) = struct.unpack("!I", head[len(MAGIC):])
    if n > MAX_FRAME_BYTES:
        raise WireError(f"frame length {n} exceeds MAX_FRAME_BYTES "
                        f"({MAX_FRAME_BYTES}) — corrupt length prefix")
    body = _read_exact(sock, n)
    if body is None:
        raise ConnectionError("peer closed mid-frame")
    return body


def _read_exact(sock, n: int) -> Optional[bytes]:
    """Exactly n bytes, or None on EOF at a frame boundary; EOF inside
    a frame raises ConnectionError."""
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf
