"""The serve wire protocol (schema ``serve-wire/v1``).

Two message families cross a transport:

* ``UploadMsg`` — client -> server.  ``kind="report"`` carries the
  policy's declared scalars (Eq. 1 value / gradient norm) so the SERVER
  makes the ship/skip decision with exact policy state (VAFL's
  above-mean gate is fleet-wide — no client can evaluate it alone);
  ``kind="update"`` carries the model payload of an accepted upload
  (a :class:`repro.compress.Payload` delta under a codec, the full
  parameter tree under identity).

* ``BroadcastMsg`` — server -> client.  ``kind="init"`` bootstraps a
  client (initial model + the run flags it needs: which scalars to
  compute, whether the exchange is two-phase); ``kind="decision"``
  answers a report (two-phase algorithms only); ``kind="download"``
  closes every event with the latest global model; ``kind="final"``
  tells free-running clients to stop.

The two-phase exchange mirrors the paper's protocol: a 4-byte scalar
report precedes each decision, and the heavy model payload only ships
when the server says so — which is exactly what ``CommStats`` has
always accounted (reports cost 4 B; declined events cost no payload).
Decision frames themselves are control-plane traffic and are NOT billed,
matching the closed-loop runtimes where the decision is a function call.

Everything in a message is either a scalar, a ``Payload`` (numpy planes
+ picklable treedef meta) or a parameter pytree — the socket transport
pickles messages whole after converting tree leaves to numpy
(:func:`tree_to_host` / 4-byte length-prefixed frames).
"""
from __future__ import annotations

import pickle
import struct
from dataclasses import dataclass, field
from typing import Any, Optional

WIRE_SCHEMA = "serve-wire/v1"

# UploadMsg kinds
REPORT = "report"
UPDATE = "update"
# BroadcastMsg kinds
INIT = "init"
DECISION = "decision"
DOWNLOAD = "download"
FINAL = "final"


@dataclass
class UploadMsg:
    """One client -> server message.

    ``version`` is the global-model version the client last downloaded
    (its training base — the server's staleness metadata and, under a
    codec, the delta's reference).  ``seq`` is the client's own event
    counter (per-client FIFO is asserted on it), ``sim_time`` the
    client's clock (scenario-paced simulated seconds, or host seconds
    for free-running workers).  ``recv_host`` is stamped by the
    transport when the message lands server-side — the commit-latency
    clock, deliberately single-domain."""
    kind: str                      # REPORT | UPDATE
    client: int
    seq: int
    version: int
    sim_time: float = 0.0
    value: Optional[float] = None  # Eq. 1 V (policies with needs_values)
    norm: Optional[float] = None   # ||eff_grad||^2 (needs_norms)
    codec: str = "identity"
    payload: Any = None            # Payload (codec) | param tree (identity)
    enc_seed: int = 0              # the payload's deterministic encode seed
    recv_host: float = 0.0         # transport-stamped server arrival time


@dataclass
class BroadcastMsg:
    """One server -> client message (init / decision / download / final)."""
    kind: str
    version: int = 0
    tree: Any = None               # model pytree (init / download)
    upload: bool = False           # DECISION: ship the payload?
    meta: dict = field(default_factory=dict)   # INIT: run flags


def tree_to_host(tree):
    """Map a pytree's leaves to numpy so it pickles across processes
    (jax.Array pickling is version-dependent; numpy is forever).  The
    float bits are preserved exactly, so a socket hop never perturbs
    golden-seed parity."""
    import jax
    import numpy as np
    if tree is None:
        return None
    return jax.tree.map(lambda x: np.asarray(x), tree)


def msg_to_wire(msg) -> bytes:
    """Pickle one message into a 4-byte length-prefixed frame."""
    if isinstance(msg, BroadcastMsg) and msg.tree is not None:
        msg = BroadcastMsg(kind=msg.kind, version=msg.version,
                           tree=tree_to_host(msg.tree), upload=msg.upload,
                           meta=msg.meta)
    elif isinstance(msg, UploadMsg) and msg.payload is not None:
        from repro.compress.base import Payload
        if not isinstance(msg.payload, Payload):   # identity: raw tree
            msg = UploadMsg(**{**msg.__dict__,
                               "payload": tree_to_host(msg.payload)})
    body = pickle.dumps((WIRE_SCHEMA, msg), protocol=pickle.HIGHEST_PROTOCOL)
    return struct.pack("!I", len(body)) + body


def msg_from_wire(body: bytes):
    """Decode one frame body (length prefix already consumed)."""
    schema, msg = pickle.loads(body)
    if schema != WIRE_SCHEMA:
        raise ValueError(f"wire schema mismatch: got {schema!r}, "
                         f"expected {WIRE_SCHEMA!r}")
    return msg


def read_frame(sock) -> Optional[bytes]:
    """Read one length-prefixed frame from a socket; None on clean EOF
    (peer closed between frames).  A half-read frame — the peer died
    mid-send — raises ConnectionError, which the transport turns into
    the discard/failure path."""
    head = _read_exact(sock, 4)
    if head is None:
        return None
    (n,) = struct.unpack("!I", head)
    body = _read_exact(sock, n)
    if body is None:
        raise ConnectionError("peer closed mid-frame")
    return body


def _read_exact(sock, n: int) -> Optional[bytes]:
    """Exactly n bytes, or None on EOF at a frame boundary; EOF inside
    a frame raises ConnectionError."""
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf
