"""Interprocess transport: localhost TCP, length-prefixed pickle frames.

The server listens on an ephemeral loopback port; each client process
connects and sends a hello frame naming its client id, then streams
``UploadMsg`` frames while an accept/reader thread per connection pushes
them — parsed and arrival-stamped — into the same bounded internal
queue the ``inproc`` transport uses, so the ``FLServer`` hot loop is
transport-agnostic.  Broadcasts are written back on the same connection
(one writer lock per socket).

Failure semantics: a connection that dies mid-frame (killed worker) or
fails the frame checks (``WireError``: bad magic, oversized length,
undecodable body) raises on the reader thread, which records the
client as dead WITH a reason (``"disconnect"`` / ``"wire-error"``) and
enqueues nothing — the server's liveness tracker polls
``dead_clients()``/``dead_reasons()`` each step and turns them into
eviction events, and a client that reconnects (new hello on a fresh
socket) is surfaced through ``poll_reconnects()`` for re-admission
with a fresh decode base (docs/RESILIENCE.md).  Per-client FIFO holds
because TCP preserves byte order per connection.

Payload trees are converted to numpy before pickling
(``messages.tree_to_host``) — float bits survive the hop exactly.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from typing import Dict, List, Optional

from repro.serve.messages import (WIRE_SCHEMA, UploadMsg, WireError,
                                  msg_from_wire, msg_to_wire, read_frame)
from repro.serve.transport import ClientChannel, Transport

_HELLO = "hello"


class _SocketChannel(ClientChannel):
    """Client-process side: one connected socket, frames both ways."""

    def __init__(self, host: str, port: int, client: int,
                 connect_timeout: float = 30.0):
        self.client = client
        self._sock = socket.create_connection((host, port),
                                              timeout=connect_timeout)
        self._sock.settimeout(None)
        self._lock = threading.Lock()
        self._sock.sendall(msg_to_wire((_HELLO, client)))

    def send(self, msg: UploadMsg, timeout: Optional[float] = None) -> bool:
        # TCP's own flow control is the backpressure: sendall blocks when
        # the server-side bounded queue stops draining the socket buffer
        with self._lock:
            self._sock.sendall(msg_to_wire(msg))
        return True

    def recv(self, timeout: Optional[float] = None):
        self._sock.settimeout(timeout if timeout else 0.001)
        try:
            body = read_frame(self._sock)
        except socket.timeout:
            return None
        except WireError:
            # a corrupt server->client frame desyncs the stream; close
            # so the next send fails loudly (the worker loop bails, the
            # server's liveness deadline evicts) instead of misparsing
            self.close()
            return None
        finally:
            try:
                self._sock.settimeout(None)
            except OSError:
                pass
        return None if body is None else msg_from_wire(body)

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


class SocketTransport(Transport):
    """Server side: listener + one reader thread per accepted client."""

    name = "socket"

    def __init__(self, num_clients: int, capacity: int = 0,
                 host: str = "127.0.0.1"):
        self.num_clients = num_clients
        self._uploads: queue.Queue = queue.Queue(maxsize=capacity)
        self._conns: Dict[int, socket.socket] = {}
        self._send_locks: Dict[int, threading.Lock] = {}
        # broadcasts addressed to a client that hasn't connected yet
        # (e.g. the init broadcast racing a slow process spawn) wait in
        # a per-client buffer and flush — in order, under the same send
        # lock — the moment its hello lands
        self._pending_bcast: Dict[int, List[bytes]] = {}
        self._dead: set = set()
        # why each dead client died ("disconnect" | "wire-error") and
        # which dead clients have since presented a fresh hello — the
        # server's liveness tracker drains both surfaces every step
        self._dead_reasons: Dict[int, str] = {}
        self._reconnected: set = set()
        self._threads: List[threading.Thread] = []
        self._closing = False
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, 0))
        self._listener.listen(num_clients)
        self.address = self._listener.getsockname()   # (host, port)
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name="serve-accept")
        t.start()
        self._threads.append(t)

    # ------------------------------------------------- server internals ---

    def _accept_loop(self) -> None:
        while not self._closing:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return   # listener closed
            t = threading.Thread(target=self._reader, args=(conn,),
                                 daemon=True, name="serve-reader")
            t.start()
            self._threads.append(t)

    def _reader(self, conn: socket.socket) -> None:
        client = None
        try:
            hello = msg_from_wire(read_frame(conn))
            if not (isinstance(hello, tuple) and hello[0] == _HELLO):
                raise ConnectionError("expected hello frame")
            client = int(hello[1])
            with self._lock_for(client):
                if client in self._dead:
                    # a previously-dead client came back on a fresh
                    # socket: clear the tombstone and surface the
                    # reconnect so the server can re-admit it (fresh
                    # init broadcast, fresh decode base)
                    self._dead.discard(client)
                    self._dead_reasons.pop(client, None)
                    self._reconnected.add(client)
                self._conns[client] = conn
                for frame in self._pending_bcast.pop(client, []):
                    conn.sendall(frame)
            while True:
                body = read_frame(conn)
                if body is None:
                    return                     # clean close
                msg = msg_from_wire(body)
                msg.recv_host = time.monotonic()
                self._uploads.put(msg)         # bounded: blocks the reader
        except WireError:
            # corrupt/truncated/oversized frame: the structured failure
            # path — the stream past it is garbage, so the client is
            # dead until it reconnects; the server counts a wire error
            self._mark_dead(client, "wire-error")
        except (ConnectionError, OSError):
            self._mark_dead(client, "disconnect")
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _mark_dead(self, client: Optional[int], reason: str) -> None:
        if client is not None:
            self._dead.add(client)
            self._dead_reasons[client] = reason

    # -------------------------------------------------------- Transport ---

    def recv_upload(self, timeout: Optional[float] = None
                    ) -> Optional[UploadMsg]:
        try:
            if timeout:
                return self._uploads.get(timeout=timeout)
            return self._uploads.get_nowait()
        except queue.Empty:
            return None

    def queue_depth(self) -> int:
        return self._uploads.qsize()

    def dead_clients(self) -> set:
        """Clients whose connection died mid-stream (discard path)."""
        return set(self._dead)

    def dead_reasons(self) -> Dict[int, str]:
        """Why each currently-dead client died: ``"disconnect"`` (peer
        vanished) or ``"wire-error"`` (corrupt frame tripped the
        ``MAGIC``/size/decode checks)."""
        return dict(self._dead_reasons)

    def poll_reconnects(self) -> set:
        """Drain the set of clients that reconnected (fresh hello after
        being marked dead) since the last poll — the server re-admits
        each with a fresh init broadcast."""
        out, self._reconnected = self._reconnected, set()
        return out

    def _lock_for(self, client: int) -> threading.Lock:
        # dict.setdefault is GIL-atomic: concurrent first touches from
        # the reader thread and the serve loop agree on one lock
        return self._send_locks.setdefault(client, threading.Lock())

    def send_broadcast(self, client: int, msg) -> None:
        if client in self._dead:
            return   # never wedge on (or buffer for) a dead client
        frame = msg_to_wire(msg)
        with self._lock_for(client):
            conn = self._conns.get(client)
            if conn is None:
                # not connected yet: hold the frame for the hello flush
                self._pending_bcast.setdefault(client, []).append(frame)
                return
            try:
                conn.sendall(frame)
            except OSError:
                self._mark_dead(client, "disconnect")

    def client_channel(self, client: int) -> ClientChannel:
        host, port = self.address
        return _SocketChannel(host, port, client)

    def close(self) -> None:
        self._closing = True
        try:
            self._listener.close()
        except OSError:
            pass
        for conn in list(self._conns.values()):
            try:
                conn.close()
            except OSError:
                pass
