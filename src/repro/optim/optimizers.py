"""Functional optimizers (optax-style (init, update) pairs, self-contained).

Each optimizer is a factory returning ``(init_fn, update_fn)``:
    state = init_fn(params)
    updates, state = update_fn(grads, state, params, step)
    params = apply_updates(params, updates)
Learning rates may be floats or schedule callables ``step -> lr``.
"""
from __future__ import annotations

from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable]


def _lr_at(lr: Schedule, step):
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def clip_by_global_norm(grads, max_norm: float):
    from repro.common.pytree import global_norm
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def sgd(lr: Schedule, momentum: float = 0.0, nesterov: bool = False):
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads), state
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -(lr_t * (momentum * m + g.astype(jnp.float32))),
                               mu, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, {"mu": mu}

    return init, update


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    return adamw(lr, b1=b1, b2=b2, eps=eps, weight_decay=0.0)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        t = jnp.asarray(step, jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
        vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
        upd = jax.tree.map(lambda mh, vh: -lr_t * mh / (jnp.sqrt(vh) + eps), mhat, vhat)
        if weight_decay:
            upd = jax.tree.map(lambda u, p: u - lr_t * weight_decay * p.astype(jnp.float32),
                               upd, params)
        return upd, {"m": m, "v": v}

    return init, update
