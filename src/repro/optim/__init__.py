from repro.optim.optimizers import (adam, adamw, apply_updates, clip_by_global_norm,
                                    sgd)
from repro.optim.schedules import constant, cosine, wsd
