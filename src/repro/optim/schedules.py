"""LR schedules, including WSD (warmup-stable-decay) from MiniCPM
[arXiv:2404.06395] — required by the minicpm_2b training recipe."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return sched


def wsd(peak: float, warmup: int, stable: int, decay: int, floor_ratio: float = 0.1):
    """Warmup-Stable-Decay: linear warmup -> flat peak -> exponential-ish
    decay to floor_ratio*peak over `decay` steps (MiniCPM's schedule)."""
    floor = peak * floor_ratio

    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak * s / jnp.maximum(warmup, 1)
        in_decay = jnp.clip((s - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
        dec = peak * (floor_ratio ** in_decay)  # exponential decay to floor
        out = jnp.where(s < warmup, warm, jnp.where(s < warmup + stable, peak, dec))
        return jnp.maximum(out, jnp.where(s >= warmup + stable + decay, floor, 0.0))
    return sched
